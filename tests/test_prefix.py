"""Prefix-state sharing (SSM analogue of SPA, DESIGN.md §Arch-applicability):
continuing K responses from one shared prompt state must be token-exact vs
running [prompt + response] per sample, including across the conv boundary,
and the gradients must match the per-sample sum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.prefix import shared_prompt_logprobs
from repro.models import forward_hidden, init, token_logprobs


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("mamba2-2.7b"))
    assert cfg.family == "ssm"
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _data(cfg, Lp=13, Lr=6, K=3, seed=2):
    rng = np.random.RandomState(seed)
    prompt = rng.randint(3, cfg.vocab_size, size=(1, Lp)).astype(np.int32)
    resp = rng.randint(3, cfg.vocab_size, size=(K, Lr)).astype(np.int32)
    rows = np.concatenate(
        [np.broadcast_to(prompt[:, -1:], (K, 1)), resp], axis=1)  # (K, 1+Lr)
    labels = np.concatenate([resp, np.zeros((K, 1), np.int32)], axis=1)
    return (jnp.asarray(prompt), jnp.asarray(rows), jnp.asarray(labels),
            jnp.asarray(resp))


def _per_sample_logprobs(params, cfg, prompt, resp):
    """Full [prompt + response] forward per sample — the oracle."""
    K, Lr = resp.shape
    Lp = prompt.shape[1]
    full = jnp.concatenate(
        [jnp.broadcast_to(prompt, (K, Lp)), resp], axis=1)
    h, _, _, _ = forward_hidden(params, cfg, full)
    # positions Lp-1 .. Lp+Lr-1 predict r_0..r_{Lr-1}
    labels = jnp.concatenate([resp, jnp.zeros((K, 1), jnp.int32)], axis=1)
    lp = token_logprobs(params, cfg, h[:, Lp - 1:], labels)
    return lp[:, :Lr]


def test_prefix_sharing_token_exact(setup):
    cfg, params = setup
    prompt, rows, labels, resp = _data(cfg)
    lp_shared = shared_prompt_logprobs(params, cfg, prompt, rows, labels)
    lp_ref = _per_sample_logprobs(params, cfg, prompt, resp)
    np.testing.assert_allclose(np.asarray(lp_shared[:, :resp.shape[1]]),
                               np.asarray(lp_ref), atol=2e-4, rtol=2e-4)


def test_prefix_sharing_gradient_exact(setup):
    """grad(shared prompt pass, responses continue) == grad(per-sample sum):
    autodiff accumulates the K response cotangents into the single prompt
    pass — the SPA gradient-exactness claim, in state space."""
    cfg, params = setup
    prompt, rows, labels, resp = _data(cfg)
    Lr = resp.shape[1]

    def loss_shared(p):
        lp = shared_prompt_logprobs(p, cfg, prompt, rows, labels)
        return lp[:, :Lr].sum()

    def loss_ref(p):
        return _per_sample_logprobs(p, cfg, prompt, resp).sum()

    g_a = jax.grad(loss_shared)(params)
    g_b = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree.leaves(g_a), jax.tree.leaves(g_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-3, rtol=3e-3)


def test_prefix_sharing_cross_response_isolation(setup):
    """Perturbing response j must not change response i's log-probs (the
    state is shared read-only)."""
    cfg, params = setup
    prompt, rows, labels, resp = _data(cfg)
    Lr = resp.shape[1]
    base = np.asarray(
        shared_prompt_logprobs(params, cfg, prompt, rows, labels))
    rows2 = np.asarray(rows).copy()
    rows2[1, 1:] = 7  # trash response 1's tokens
    pert = np.asarray(shared_prompt_logprobs(
        params, cfg, prompt, jnp.asarray(rows2), labels))
    np.testing.assert_allclose(pert[0, :Lr], base[0, :Lr], atol=1e-5)
    np.testing.assert_allclose(pert[2, :Lr], base[2, :Lr], atol=1e-5)
