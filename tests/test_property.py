"""Hypothesis property tests on the system's invariants.

Covered invariants:
  * SPA packing: per-response loss weights sum to 1, positions restart at
    |prompt|-1, segments never collide across responses, labels align.
  * pack_plain vs pack_spa: identical total sample count and label multiset.
  * GradAccumulator: weighted mean is order-invariant and scale-correct.
  * group_advantages: zero-mean, scale-bounded.
  * Tokenizer: encode/decode round-trip for arbitrary unicode.
  * extract_answer: finds the first integer exactly.
  * spa_reduction_ratio: Eq. 5 bounds (rho <= 1 + 1/K, rho -> 1/K).
  * Adam: step with zero grads only applies weight decay; finite updates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

given, settings, st = require_hypothesis()

from repro.core.queue import RolloutGroup
from repro.core.spa import PAD, pack_plain, pack_spa, spa_reduction_ratio
from repro.data.tasks import extract_answer
from repro.data.tokenizer import Tokenizer
from repro.optim.accumulate import GradAccumulator
from repro.optim.adam import adam_init, adam_update
from repro.rl.grpo import group_advantages

SETTINGS = settings(max_examples=30, deadline=None)


# --------------------------------------------------------------------------
# strategies
# --------------------------------------------------------------------------

@st.composite
def rollout_groups(draw):
    Lp = draw(st.integers(2, 20))
    G = draw(st.integers(1, 6))
    T = draw(st.integers(1, 12))
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    prompt = rng.randint(3, 250, size=(Lp,)).astype(np.int32)
    resp = np.zeros((G, T), np.int32)
    lens = np.zeros((G,), np.int32)
    for g in range(G):
        n = rng.randint(1, T + 1)
        resp[g, :n] = rng.randint(3, 250, size=(n,))
        lens[g] = n
    rewards = rng.rand(G).astype(np.float32)
    return RolloutGroup(uid=0, prompt_ids=prompt, response_ids=resp,
                        response_len=lens, rewards=rewards, weight_version=0)


# --------------------------------------------------------------------------
# SPA packing invariants
# --------------------------------------------------------------------------

@SETTINGS
@given(rollout_groups(), st.integers(1, 4))
def test_spa_pack_invariants(group, K):
    G = group.response_ids.shape[0]
    Lp = len(group.prompt_ids)
    T = group.response_ids.shape[1]
    adv = np.asarray(group_advantages(jnp.asarray(group.rewards)))
    mb = pack_spa(group, adv, max_prompt_len=Lp, max_response_len=T,
                  responses_per_row=K)
    assert float(mb.n_samples) == G
    n_rows = int(np.ceil(G / K))
    assert mb.tokens.shape[0] == n_rows
    j = 0
    for row in range(n_rows):
        seg = mb.segments[row]
        pos = mb.positions[row]
        w = mb.loss_mask[row]
        toks = mb.tokens[row]
        # shared prompt prefix
        assert (seg[: Lp - 1] == 0).all()
        assert (pos[: Lp - 1] == np.arange(Lp - 1)).all()
        off = Lp - 1
        for k in range(K):
            if j >= G:
                # empty slot: stays padding
                assert (seg[off:] <= 0).all()
                break
            lr = int(group.response_len[j])
            sl = slice(off, off + 1 + lr)
            assert (seg[sl] == k + 1).all()
            assert toks[off] == group.prompt_ids[-1]   # last prompt token copy
            assert pos[off] == Lp - 1                  # position restart
            np.testing.assert_allclose(w[off: off + lr].sum(), 1.0, rtol=1e-5)
            # labels predict exactly the response tokens
            np.testing.assert_array_equal(
                mb.labels[row, off: off + lr],
                group.response_ids[j, :lr])
            j += 1
            off += 1 + T
    assert j == G


@SETTINGS
@given(rollout_groups())
def test_plain_pack_invariants(group):
    G = group.response_ids.shape[0]
    Lp = len(group.prompt_ids)
    T = group.response_ids.shape[1]
    adv = np.asarray(group_advantages(jnp.asarray(group.rewards)))
    mb = pack_plain([group], [adv], Lp, T)
    assert mb.tokens.shape[0] == G
    assert float(mb.n_samples) == G
    for g in range(G):
        lr = int(group.response_len[g])
        np.testing.assert_allclose(mb.loss_mask[g].sum(), 1.0, rtol=1e-5)
        # weights sit exactly on the positions predicting response tokens
        nz = np.nonzero(mb.loss_mask[g])[0]
        np.testing.assert_array_equal(nz, np.arange(Lp - 1, Lp - 1 + lr))
        np.testing.assert_array_equal(mb.labels[g, Lp - 1: Lp - 1 + lr],
                                      group.response_ids[g, :lr])
        # advantage constant over the row
        assert (mb.advantages[g] == adv[g]).all()


@SETTINGS
@given(rollout_groups(), st.integers(1, 4))
def test_spa_and_plain_same_labels(group, K):
    """Both packings must expose the same multiset of (label, weight>0)
    pairs — they are two layouts of the same loss."""
    Lp = len(group.prompt_ids)
    T = group.response_ids.shape[1]
    adv = np.asarray(group_advantages(jnp.asarray(group.rewards)))
    a = pack_plain([group], [adv], Lp, T)
    b = pack_spa(group, adv, Lp, T, responses_per_row=K)

    def labelled(mb):
        lab = mb.labels[mb.loss_mask > 0]
        return sorted(lab.tolist())

    assert labelled(a) == labelled(b)


# --------------------------------------------------------------------------
# gradient accumulation (Eq. 1)
# --------------------------------------------------------------------------

@SETTINGS
@given(st.lists(st.tuples(st.floats(-10, 10), st.floats(0.5, 4.0)),
                min_size=1, max_size=10),
       st.randoms(use_true_random=False))
def test_accumulator_weighted_mean_order_invariant(items, rnd):
    acc1, acc2 = GradAccumulator(), GradAccumulator()
    for g, w in items:
        acc1.add({"x": jnp.float32(g)}, w)
    shuffled = list(items)
    rnd.shuffle(shuffled)
    for g, w in shuffled:
        acc2.add({"x": jnp.float32(g)}, w)
    want = sum(g * w for g, w in items) / sum(w for _, w in items)
    np.testing.assert_allclose(float(acc1.mean()["x"]), want,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(acc1.mean()["x"]),
                               float(acc2.mean()["x"]), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# GRPO advantages
# --------------------------------------------------------------------------

@SETTINGS
@given(st.lists(st.floats(0, 1), min_size=2, max_size=32))
def test_advantages_zero_mean_bounded(rs):
    a = np.asarray(group_advantages(jnp.asarray(rs, jnp.float32)))
    assert np.isfinite(a).all()
    sd = np.asarray(rs, np.float32).std()
    if sd < 1e-6:
        # (near-)constant rewards: the eps in (r - mu)/(sd + eps) amplifies
        # f32 rounding of the mean — advantages must merely be negligible
        assert np.abs(a).max() < 1e-2
    else:
        np.testing.assert_allclose(a.mean(), 0.0, atol=1e-4)
    if sd > 1e-3:
        assert np.abs(a).max() < (1.0 / sd) + 1.0   # standardisation bound


# --------------------------------------------------------------------------
# tokenizer / reward substrate
# --------------------------------------------------------------------------

@SETTINGS
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(text):
    tok = Tokenizer(512)
    ids = tok.encode(text, bos=True, eos=True)
    assert ids[0] == Tokenizer.BOS and ids[-1] == Tokenizer.EOS
    assert tok.decode(ids) == text


@SETTINGS
@given(st.integers(-10**6, 10**6),
       st.text(alphabet=list("abc xyz.,!?"), max_size=30))
def test_extract_answer_finds_first_int(n, noise):
    # the first integer in the text must be returned
    assert extract_answer(f"{noise} {n} trailing 99") == n


def test_extract_answer_none_on_no_digits():
    assert extract_answer("no numbers here -") is None


# --------------------------------------------------------------------------
# Eq. 5 reduction ratio
# --------------------------------------------------------------------------

@SETTINGS
@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 64))
def test_spa_rho_bounds(Lp, Lr, K):
    rho = spa_reduction_ratio(Lp, float(Lr), K)
    assert 0 < rho <= 1.0 + 1.0 / K + 1e-9
    # monotone improvement with longer prompts (fixed Lr, K)
    rho2 = spa_reduction_ratio(Lp * 4, float(Lr), K)
    if K > 1:
        assert rho2 <= rho + 1e-9


# --------------------------------------------------------------------------
# Adam (Table 7 settings)
# --------------------------------------------------------------------------

@SETTINGS
@given(st.floats(1e-7, 1e-2), st.integers(0, 2**31 - 1))
def test_adam_finite_and_moving(lr, seed):
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (8, 8), jnp.float32)}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8, 8))}
    st0 = adam_init(params)
    new_p, st1, m = adam_update(params, grads, st0, lr=lr)
    assert int(st1.step) == 1
    assert np.isfinite(np.asarray(new_p["w"])).all()
    assert np.isfinite(float(m["grad_norm"]))
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) > 0


def test_adam_grad_clip_caps_update():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    st0 = adam_init(params)
    new_p, _, m = adam_update(params, huge, st0, lr=1.0, weight_decay=0.0,
                              grad_clip=1.0)
    assert float(m["grad_norm"]) > 1e5
    # post-clip step is bounded by lr / (1 - b1-ish); just require sane scale
    assert float(jnp.abs(new_p["w"]).max()) < 10.0


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------

def test_warmup_cosine_schedule_shape():
    from repro.optim.schedule import constant, warmup_cosine
    lr = 1e-3
    fn = warmup_cosine(lr, warmup=10, total=100, floor=0.1)
    assert float(fn(0)) == 0.0
    np.testing.assert_allclose(float(fn(10)), lr, rtol=1e-6)
    assert float(fn(100)) < float(fn(50)) < float(fn(10))
    np.testing.assert_allclose(float(fn(100)), lr * 0.1, rtol=1e-5)
    np.testing.assert_allclose(float(constant(lr)(123)), lr, rtol=1e-6)
