"""Radix prefix cache — the oracle token-identity battery (DESIGN.md
§Radix-prefix-cache).

The exactness contract, extended to the serving tier: requests served
through the radix cache (shared prompt pages + suffix-only prefill) must
be BITWISE token-identical to cold-cache serving under the same keys,
across GQA / MLA-latent / sliding-window cache backends, with and without
the spec plane riding on top. This holds because a paged cache entry is a
pure function of (token, position) — a cached page IS the page a cold
prefill would write — and because sampling is scheduling-order-invariant
(per-request fold_in keys + stepwise step keys), so the warm engine's
different admission timing cannot perturb the draws.

Also here: the regression proof for the deleted teacher-forced serving
path — the old forced path was proven token-identical to greedy decode of
the full prompt (system + suffix) by the previous test generation, so the
new radix path showing the same identity chains the two implementations.

Structural invariants (refcounts, conservation, LRU eviction) are fuzzed
in tests/test_radix_property.py; this file goes through the real model.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import engine_support
from repro.core.paged import FIRST_PAGE, PagedGroupEngine
from repro.core.radix import RadixCache
from repro.models import init

K = 3            # spec depth when the spec plane rides along
LP, T = 24, 10   # engine prompt/response caps
PAGE = 4


def _gqa():
    return reduced_config(get_config("llama3.2-3b"))


def _mla_nomoe():
    c = reduced_config(get_config("deepseek-v2-lite-16b"))
    return dataclasses.replace(c, num_experts=0, num_experts_per_tok=0,
                               num_shared_experts=0, moe_d_ff=0,
                               first_k_dense=0, dense_d_ff=0)


def _swa():
    return dataclasses.replace(_gqa(), sliding_window=8)


VARIANTS = {"gqa": _gqa, "mla": _mla_nomoe, "swa": _swa}


@pytest.fixture(scope="module")
def setups():
    out = {}
    for name, mk in VARIANTS.items():
        cfg = mk()
        out[name] = (cfg, init(jax.random.PRNGKey(0), cfg))
    return out


SYSTEM = [1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 3, 4]      # three full pages


def _prompts(n_reqs, tail=2):
    return [np.asarray(SYSTEM + [40 + tail * i + d for d in range(tail)],
                       np.int32) for i in range(n_reqs)]


def _serve(cfg, params, prompts, *, prefix_cache, spec_k=0,
           temperature=0.7, num_pages=64, num_slots=3):
    eng = PagedGroupEngine(cfg, num_slots=num_slots, page_size=PAGE,
                           num_pages=num_pages, max_prompt_len=LP,
                           max_new_tokens=T, group_size=1,
                           temperature=temperature, capture_logprobs=False,
                           spec_k=spec_k, prefix_cache=prefix_cache, seed=0)
    eng.set_params(params)
    hs = [eng.submit(p, jax.random.fold_in(jax.random.PRNGKey(3), i))
          for i, p in enumerate(prompts)]
    while eng.step():
        pass
    outs = []
    for h in hs:
        r = h.result(timeout=1)
        n = int(np.asarray(r.response_len)[0])
        outs.append(np.asarray(r.response_ids)[0, :n].tolist())
    return outs, eng


# =========================================================================
# the exactness contract, backend by backend
# =========================================================================

@pytest.mark.parametrize("variant", ["gqa", "mla", "swa"])
@pytest.mark.parametrize("spec_k", [0, K])
def test_radix_token_identity(setups, variant, spec_k):
    """Radix-served sampled decode == cold-cache sampled decode, bitwise,
    on every paged backend, with and without spec decode — and the warm
    run actually hit the cache (the identity is not vacuous)."""
    cfg, params = setups[variant]
    prompts = _prompts(4)
    cold, _ = _serve(cfg, params, prompts, prefix_cache=False,
                     spec_k=spec_k)
    warm, eng = _serve(cfg, params, prompts, prefix_cache=True,
                       spec_k=spec_k)
    assert cold == warm
    assert eng.prefix_hit_pages > 0 and eng.prefix_hit_rate > 0
    # drained pool: free + referenced == capacity, tree holds one
    # reference per cached page and nothing else does
    assert eng.idle
    assert eng.alloc.num_free + eng.alloc.num_live == eng.P - FIRST_PAGE
    tree = eng.radix.pages()
    assert sorted(tree) == sorted(set(tree))
    assert all(eng.alloc.refcount(p) == 1 for p in tree)


def test_radix_cross_time_reuse(setups):
    """Pages cached by a DRAINED first wave serve a later wave: the tree
    reference outlives every row that wrote the pages (the cross-time
    sharing the per-group refcount machinery alone cannot do)."""
    cfg, params = setups["gqa"]
    eng = PagedGroupEngine(cfg, num_slots=2, page_size=PAGE, num_pages=64,
                           max_prompt_len=LP, max_new_tokens=T,
                           group_size=1, temperature=0.0,
                           capture_logprobs=False, prefix_cache=True, seed=0)
    eng.set_params(params)
    waves = []
    for w in range(2):
        hs = [eng.submit(p, jax.random.fold_in(jax.random.PRNGKey(w), i))
              for i, p in enumerate(_prompts(2))]
        while eng.step():
            pass
        waves.append([h.result(1) for h in hs])
        if w == 0:
            hits_wave1 = eng.prefix_hit_pages
    assert eng.idle
    # wave 2's requests hit the pages wave 1 cached — all three system
    # pages for both requests, despite every wave-1 row being long gone
    assert eng.prefix_hit_pages - hits_wave1 >= 2 * (len(SYSTEM) // PAGE)
    # greedy: identical prompts across waves emit identical tokens
    for a, b in zip(waves[0], waves[1]):
        np.testing.assert_array_equal(np.asarray(a.response_ids),
                                      np.asarray(b.response_ids))


@pytest.mark.parametrize("spec_k", [0, 2])
def test_serve_shared_matches_cold_full_prompt_greedy(setups, spec_k):
    """Regression for the deleted teacher-forced serve_shared: greedily,
    the radix path must emit exactly what cold full-prompt serving emits
    (which is what the forced path was previously proven identical to)."""
    from repro.launch.serve import serve_paged, serve_shared
    cfg, _ = setups["gqa"]
    system = np.arange(1, 10, dtype=np.int32)
    sufs = [np.asarray([20, 21], np.int32), np.asarray([30], np.int32),
            np.asarray([40, 41, 42], np.int32)]
    done, stats = serve_shared(cfg, system, sufs, max_prompt_len=LP,
                               max_new=T, page_size=PAGE, seed=0,
                               temperature=0.0, spec_k=spec_k)
    full = [np.concatenate([system, s]) for s in sufs]
    ref, _ = serve_paged(cfg, full, max_prompt_len=LP, max_new=T,
                         num_slots=len(sufs), page_size=PAGE, seed=0,
                         temperature=0.0, spec_k=spec_k)
    by_rid = {c.request_id: c.response_ids for c in ref}
    for c in done:
        np.testing.assert_array_equal(c.response_ids, by_rid[c.request_id])
    assert stats["prefix_hit_rate"] > 0


def test_window_dead_prompt_pages_never_cached(setups):
    """Sliding-window geometry: prompt pages before j0 are never
    allocated, so the tree holds placeholders there and caches only the
    window-visible tail — and a second identical prompt still matches it
    (the walk navigates placeholders by token content)."""
    cfg, params = setups["swa"]          # window 8, page 4 -> j0 = 1
    prompts = _prompts(2, tail=2)        # 14 tokens: j0=1, full pages 0..2
    _, eng = _serve(cfg, params, prompts, prefix_cache=True)
    # cached: pages j0..(len-1)//PAGE-1 = indices 1, 2 only
    assert eng.radix.cached_pages == 2
    assert eng.prefix_hit_pages == 2     # second request matched both


def test_prefix_plane_support_matrix():
    """The prefix plane inherits exactly the paged exclusions — SSM,
    hybrid, enc-dec and VLM families are rejected at construction with
    the architectural reason."""
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok_paged, _ = engine_support(cfg, "paged")
        ok_prefix, reason = engine_support(cfg, "prefix")
        assert ok_prefix == ok_paged, (arch, reason)
    bad = get_config("mamba2-2.7b")
    with pytest.raises(ValueError, match="not applicable"):
        PagedGroupEngine(bad, num_slots=1, page_size=4, num_pages=16,
                         max_prompt_len=8, max_new_tokens=8, group_size=1,
                         prefix_cache=True)


def test_radix_rejects_partial_page_insert():
    """The tree only caches COMPLETE page spans — a partial trailing page
    is row-private by construction, and handing one to insert is a bug."""
    from repro.core.paged import PageAllocator
    alloc = PageAllocator(8)
    radix = RadixCache(4, alloc)
    pages = alloc.alloc(1)
    with pytest.raises(AssertionError):
        radix.insert(np.asarray([1, 2, 3], np.int32), {0: pages[0]})
