"""Radix-tree structural invariants under random interleaved
insert/match/evict/release sequences, checked against a dict-of-tuples
oracle (DESIGN.md §Radix-prefix-cache).

The oracle maps each page-aligned token prefix (as a tuple) to the page id
caching it — the flat view of the tree. The simulated workload mirrors the
engine's admission protocol: lookup, retain matched pages for a "row",
allocate the rest, insert the completed spans, and eventually release the
row's references. After EVERY operation:

  * refcounts never go negative (the allocator asserts on over-release);
  * the matched prefix is always the LONGEST cached one (oracle compare);
  * evicting a zero-ref node frees exactly its pages — each evicted page
    was cached, held only the tree's reference, capped a cached chain (no
    cached descendant), and is back on the freelist afterwards;
  * total pages are conserved: freelist + referenced == pool capacity,
    and the tree's page set is exactly the oracle's.

A seeded numpy fuzz always runs (deterministic, no extra deps); when
``hypothesis`` is installed the same exerciser also runs under ``@given``
with minimization. The through-the-model identity battery is
tests/test_radix.py.
"""
import numpy as np
import pytest

from repro.core.paged import FIRST_PAGE, PageAllocator
from repro.core.radix import RadixCache

POOL = 34                 # physical pages (32 usable after the reserves)
PAGE = 4
ROOTS = 3                 # distinct 2-page system prompts to share


def _mk_seq(rng) -> np.ndarray:
    """Prompts with real prefix sharing: one of a few shared roots plus a
    random tail (tails collide sometimes too — small alphabet)."""
    root = int(rng.randint(ROOTS))
    base = [100 * root + d for d in range(2 * PAGE)]
    tail = [int(t) for t in rng.randint(0, 5, size=rng.randint(1, 11))]
    return np.asarray(base + tail, np.int32)


def _oracle_longest(oracle, seq):
    """Longest contiguous-from-root cached prefix run, capped so the last
    token is never matched — the reference for RadixCache.lookup."""
    limit = (len(seq) - 1) // PAGE
    pages = []
    for j in range(limit):
        key = tuple(int(t) for t in seq[: (j + 1) * PAGE])
        if key not in oracle:
            break
        pages.append(oracle[key])
    return len(pages), pages


def _check_invariants(alloc, radix, oracle, rows):
    assert alloc.num_free + alloc.num_live == POOL - FIRST_PAGE
    tree = radix.pages()
    assert len(tree) == len(set(tree)) == radix.cached_pages == len(oracle)
    assert set(tree) == set(oracle.values())
    held = {}
    for pages in rows.values():
        for p in pages:
            held[p] = held.get(p, 0) + 1
    for p in tree:
        # one tree reference on top of whatever in-flight rows hold
        assert alloc.refcount(p) == 1 + held.get(p, 0)
    for p, n in held.items():
        assert alloc.refcount(p) >= n


def _exercise(seed: int, n_ops: int = 120) -> dict:
    rng = np.random.RandomState(seed)
    alloc = PageAllocator(POOL)
    radix = RadixCache(PAGE, alloc)
    oracle = {}            # prefix tuple -> page id
    rows = {}              # row id -> page list (admission references)
    next_row = 0
    stats = {"insert": 0, "match": 0, "evict": 0, "release": 0, "full": 0}

    for _ in range(n_ops):
        op = rng.choice(["insert", "match", "evict", "release"],
                        p=[0.45, 0.2, 0.15, 0.2])
        if op == "release" and not rows:
            op = "insert"
        if op == "insert":
            seq = _mk_seq(rng)
            m, mpages = radix.lookup(seq)
            om, opages = _oracle_longest(oracle, seq)
            assert (m, mpages) == (om, opages), \
                "matched prefix is not the longest cached one"
            nfull = len(seq) // PAGE
            need = nfull - m
            if alloc.num_free < need:
                protect = set(mpages)
                freed = radix.evict(need - alloc.num_free, protect=protect)
                stats["evict"] += len(freed)
                for p in freed:
                    key = next(k for k, v in oracle.items() if v == p)
                    del oracle[key]
                    assert not p in protect
            if alloc.num_free < need:
                stats["full"] += 1     # rows hold too much; skip admission
                _check_invariants(alloc, radix, oracle, rows)
                continue
            alloc.retain(mpages)       # the row's reference on matched pages
            new = alloc.alloc(need)
            inserted = radix.insert(
                seq, {j: new[j - m] for j in range(m, nfull)})
            # lookup caps the match at (len-1)//PAGE, so when len is a
            # page multiple the final page may already be cached: insert
            # skips it and the fresh page stays row-private — exactly the
            # engine's recompute-the-last-token behavior.
            fresh = []
            for j in range(m, nfull):
                key = tuple(int(t) for t in seq[: (j + 1) * PAGE])
                if key not in oracle:
                    oracle[key] = new[j - m]
                    fresh.append(key)
            assert inserted == len(fresh), \
                "insert cached a page the oracle says was already covered"
            rows[next_row] = mpages + new
            next_row += 1
            stats["insert"] += 1
        elif op == "match":
            seq = _mk_seq(rng)
            assert radix.lookup(seq) == _oracle_longest(oracle, seq)
            stats["match"] += 1
        elif op == "evict":
            n = int(rng.randint(1, 4))
            before = {p: alloc.refcount(p) for p in radix.pages()}
            freed = radix.evict(n)
            assert len(freed) <= n
            for p in freed:
                # was cached with ONLY the tree's reference...
                assert before[p] == 1
                key = next(k for k, v in oracle.items() if v == p)
                # ...capped a cached chain (no cached descendant)...
                assert not any(k != key and k[: len(key)] == key
                               for k in oracle)
                # ...and went straight back to the freelist
                assert alloc.refcount(p) == 0
                del oracle[key]
            stats["evict"] += len(freed)
        else:                          # release: a row finishes
            rid = rng.choice(list(rows))
            alloc.release(rows.pop(rid))
            stats["release"] += 1
        _check_invariants(alloc, radix, oracle, rows)
    return stats


# =========================================================================
# always-on seeded fuzz (no extra deps)
# =========================================================================

@pytest.mark.parametrize("seed", range(8))
def test_radix_fuzz_invariants(seed):
    stats = _exercise(seed)
    # the run must actually exercise the machinery, not vacuously pass
    assert stats["insert"] > 10 and stats["release"] > 0


def test_radix_fuzz_reaches_eviction_pressure():
    """At least one seed drives the pool to the eviction path and to
    admission refusal (full) — the interesting regimes."""
    agg = {"evict": 0, "full": 0}
    for seed in range(12):
        s = _exercise(seed, n_ops=150)
        agg["evict"] += s["evict"]
        agg["full"] += s["full"]
    assert agg["evict"] > 0


def test_lru_eviction_order_is_last_use():
    """Deterministic LRU check: of two evictable chains, the one touched
    least recently goes first; a lookup refreshes recency."""
    alloc = PageAllocator(POOL)
    radix = RadixCache(PAGE, alloc)
    a = np.arange(0, 8, dtype=np.int32)            # chain A: 2 pages
    b = np.arange(50, 58, dtype=np.int32)          # chain B: 2 pages
    pa = alloc.alloc(2)
    radix.insert(a, {0: pa[0], 1: pa[1]})
    pb = alloc.alloc(2)
    radix.insert(b, {0: pb[0], 1: pb[1]})
    alloc.release(pa)
    alloc.release(pb)                              # rows gone; tree-only refs
    radix.lookup(np.append(a, 9))                  # touch A
    assert radix.evict(1) == [pb[1]]               # B's deepest page is LRU
    assert radix.evict(1) == [pb[0]]               # then its parent
    assert radix.evict(1) == [pa[1]]               # then A, deepest first
    # placeholders pruned as chains empty: only A's first page remains
    assert radix.cached_pages == 1 and radix.num_nodes == 1


def test_eviction_respects_row_references_and_protect():
    """A page a row still references is not evictable; neither is a
    protected page (an in-progress admission's match)."""
    alloc = PageAllocator(POOL)
    radix = RadixCache(PAGE, alloc)
    seq = np.arange(0, 8, dtype=np.int32)
    pages = alloc.alloc(2)
    radix.insert(seq, {0: pages[0], 1: pages[1]})  # row still holds refs
    assert radix.evict(5) == []
    alloc.release([pages[1]])                      # row drops the deep page
    assert radix.evict(5, protect={pages[1]}) == []
    assert radix.evict(5) == [pages[1]]


# =========================================================================
# the same exerciser under hypothesis, when available (no env skip: the
# seeded fuzz above is the tier-1 guarantee; this adds minimization)
# =========================================================================

from conftest import optional_hypothesis

_h = optional_hypothesis()
if _h is not None:
    given, settings, st = _h

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_radix_property_hypothesis(seed):
        _exercise(seed, n_ops=60)
