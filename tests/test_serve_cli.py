"""CLI dispatch tests for ``python -m repro.launch.serve``.

The serving paths themselves are covered end-to-end by
tests/test_serving.py and tests/test_radix.py; these tests pin the
*flag wiring* — which backend ``main()`` dispatches to and with which
kwargs — by monkeypatching the four serve_* backends with recorders.
"""
import types

import numpy as np
import pytest

import repro.launch.serve as serve_mod


class Recorder:
    """Stands in for a serve_* backend: records the call, returns a
    canned result shaped like the real one."""

    def __init__(self, result):
        self.result = result
        self.calls = []

    def __call__(self, *args, **kwargs):
        self.calls.append((args, kwargs))
        return self.result


def _batch_result():
    out = types.SimpleNamespace(
        response_ids=np.zeros((8, 4), np.int32),
        response_len=np.zeros((8,), np.int32))
    return out, {"generated_tokens": 0, "wall_s": 1.0, "tok_per_s": 0.0}


def _paged_stats(spec=False, prefix=False):
    stats = {"generated_tokens": 0, "wall_s": 1.0, "tok_per_s": 0.0,
             "decode_steps": 0}
    if spec:
        stats.update(acceptance_rate=0.5, tokens_per_forward=2.0)
    if prefix:
        stats.update(prefix_hit_rate=0.25)
    return stats


def _requests_result(prefix=False):
    metrics = {"generated_tokens": 0, "ttft_p50_s": 0.01, "ttft_p99_s": 0.02,
               "tpot_p50_s": 0.001, "tpot_p99_s": 0.002, "tok_per_s": 0.0}
    stats = {"decode_steps": 0, "peak_pages": 0}
    if prefix:
        stats.update(prefix_hit_rate=0.25)
    return [], metrics, stats


def _shared_stats(spec=False):
    stats = {"generated_tokens": 0, "wall_s": 1.0, "tok_per_s": 0.0,
             "decode_steps": 0, "prefix_hit_rate": 0.5,
             "prompt_pages_saved": 3}
    if spec:
        stats.update(acceptance_rate=0.5)
    return stats


@pytest.fixture
def recorders(monkeypatch):
    recs = {
        "serve_batch": Recorder(_batch_result()),
        "serve_paged": Recorder(([], _paged_stats(spec=True, prefix=True))),
        "serve_requests": Recorder(_requests_result(prefix=True)),
        "serve_shared": Recorder(([], _shared_stats(spec=True))),
    }
    for name, rec in recs.items():
        monkeypatch.setattr(serve_mod, name, rec)
    return recs


def _only(recs, name):
    for k, r in recs.items():
        assert len(r.calls) == (1 if k == name else 0), \
            "%s called %d times" % (k, len(r.calls))
    return recs[name].calls[0]


def test_default_dispatches_to_batch(recorders, capsys):
    serve_mod.main(["--seed", "3", "--max-new", "12"])
    args, kwargs = _only(recorders, "serve_batch")
    assert kwargs["seed"] == 3 and kwargs["max_new"] == 12
    assert len(args[1]) == 8        # --num-requests default
    assert "served 8 requests" in capsys.readouterr().out


def test_paged_engine_with_prefix_cache(recorders, capsys):
    serve_mod.main(["--engine", "paged", "--prefix-cache",
                    "--slots", "2", "--page-size", "8"])
    _, kwargs = _only(recorders, "serve_paged")
    assert kwargs["prefix_cache"] is True
    assert kwargs["num_slots"] == 2 and kwargs["page_size"] == 8
    assert kwargs["spec_k"] == 0    # no --spec -> spec plane off
    assert "prefix hit rate" in capsys.readouterr().out


def test_spec_flags_reach_paged_engine(recorders, capsys):
    serve_mod.main(["--engine", "paged", "--spec", "--spec-k", "3",
                    "--spec-draft", "model"])
    _, kwargs = _only(recorders, "serve_paged")
    assert kwargs["spec_k"] == 3 and kwargs["spec_draft"] == "model"
    assert "accept=" in capsys.readouterr().out


def test_rate_dispatches_to_request_driver(recorders, capsys):
    serve_mod.main(["--engine", "paged", "--rate", "2.5",
                    "--prefix-cache", "--num-requests", "5"])
    args, kwargs = _only(recorders, "serve_requests")
    assert kwargs["rate"] == 2.5 and kwargs["prefix_cache"] is True
    assert len(args[1]) == 5
    assert "TTFT p50=" in capsys.readouterr().out


def test_shared_system_dispatches_to_serve_shared(recorders, capsys):
    serve_mod.main(["--shared-system", "6", "--spec"])
    args, kwargs = _only(recorders, "serve_shared")
    assert len(args[2]) == 6        # one suffix per request
    assert kwargs["spec_k"] == 4    # --spec-k default rides --spec
    out = capsys.readouterr().out
    assert "shared-system x6" in out and "accept=" in out


def test_spec_requires_paged_engine(recorders):
    with pytest.raises(SystemExit):
        serve_mod.main(["--spec"])
    for rec in recorders.values():
        assert rec.calls == []


def test_rate_requires_paged_engine(recorders):
    with pytest.raises(SystemExit):
        serve_mod.main(["--rate", "1.0"])


def test_prefix_cache_requires_paged_engine(recorders):
    with pytest.raises(SystemExit):
        serve_mod.main(["--prefix-cache"])
