"""Serving tier: latency metrics, the request driver, and streaming
delivery (DESIGN.md §Continuous-batching, serve.py).

Three layers, cheapest first:

  * ``compute_latency_metrics`` against an independent numpy recompute
    over a hand-scripted timestamp trace (no engine, no model);
  * ``RequestDriver`` over a VIRTUAL clock and a scripted stub engine,
    with analytically derived TTFT/TPOT — queueing delay included in
    TTFT, sleep-to-next-arrival when the engine drains, submission in
    arrival order;
  * streaming through the REAL paged engine (± spec decode): ``on_token``
    must deliver every committed token exactly once, in commit order,
    while decode is still in flight — the driver separately asserts
    stream == final response for every request it runs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.launch.serve import (RequestDriver, ServedRequest,
                                compute_latency_metrics, poisson_arrivals,
                                serve_requests)
from repro.models import init


# =========================================================================
# metrics vs independent recompute
# =========================================================================


def _scripted_requests():
    """Hand-written timestamp traces (seconds); values chosen so every
    percentile interpolation actually interpolates."""
    mk = lambda rid, arr, tt: ServedRequest(
        rid=rid, prompt=np.zeros(4, np.int32), arrival=arr,
        tokens=list(range(len(tt))), token_t=list(tt),
        done_t=tt[-1] if tt else None)
    return [
        mk(0, 0.0, [0.30, 0.40, 0.55, 0.60]),
        mk(1, 0.2, [0.90, 1.00]),
        mk(2, 0.5, [0.80, 1.10, 1.25]),
        mk(3, 1.0, [1.70]),              # single token: no TPOT sample
        mk(4, 2.0, []),                  # never served: no samples at all
    ]


def test_latency_metrics_match_numpy_recompute():
    reqs = _scripted_requests()
    m = compute_latency_metrics(reqs)
    # recompute from the raw timestamps, not via the properties under test
    ttft = np.asarray([0.30 - 0.0, 0.90 - 0.2, 0.80 - 0.5, 1.70 - 1.0])
    tpot = np.asarray([(0.60 - 0.30) / 3, (1.00 - 0.90) / 1,
                       (1.25 - 0.80) / 2])
    assert m["n_requests"] == 5
    assert m["generated_tokens"] == 4 + 2 + 3 + 1 + 0
    np.testing.assert_allclose(m["ttft_mean_s"], ttft.mean())
    np.testing.assert_allclose(m["ttft_p50_s"], np.percentile(ttft, 50))
    np.testing.assert_allclose(m["ttft_p99_s"], np.percentile(ttft, 99))
    np.testing.assert_allclose(m["tpot_mean_s"], tpot.mean())
    np.testing.assert_allclose(m["tpot_p50_s"], np.percentile(tpot, 50))
    np.testing.assert_allclose(m["tpot_p99_s"], np.percentile(tpot, 99))
    np.testing.assert_allclose(m["makespan_s"], 1.70)
    np.testing.assert_allclose(m["tok_per_s"], 10 / 1.70)


def test_latency_metrics_empty_and_degenerate():
    assert compute_latency_metrics([])["tok_per_s"] == 0.0
    only_unserved = [_scripted_requests()[4]]
    m = compute_latency_metrics(only_unserved)
    assert m["ttft_p50_s"] == 0.0 and m["tpot_p99_s"] == 0.0


def test_poisson_arrivals():
    a = poisson_arrivals(64, rate=4.0, seed=7)
    b = poisson_arrivals(64, rate=4.0, seed=7)
    c = poisson_arrivals(64, rate=4.0, seed=8)
    np.testing.assert_array_equal(a, b)          # deterministic in seed
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0) and a[0] > 0  # cumulative offsets
    gaps = np.diff(np.concatenate([[0.0], a]))
    assert 0.1 < gaps.mean() < 0.6               # ~1/rate = 0.25
    np.testing.assert_array_equal(poisson_arrivals(5, rate=0.0), np.zeros(5))


# =========================================================================
# the driver on a virtual clock + scripted engine
# =========================================================================


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def time(self) -> float:
        return self.t

    def sleep(self, seconds: float) -> None:
        assert seconds >= 0
        self.t += seconds


@dataclasses.dataclass
class _StubRow:
    script: list
    on_token: object
    emitted: list


class StubEngine:
    """Deterministic engine double with the driver-facing surface of the
    paged engine: 1-row groups, limited slots with FIFO admission at step
    start, one committed token per active row per step, each step costing
    ``dt`` seconds on the injected clock."""
    G = 1

    def __init__(self, clock, *, num_slots: int, dt: float):
        self.clock, self.slots, self.dt = clock, num_slots, dt
        self.queue, self.active, self.steps = [], [], 0

    @property
    def idle(self):
        return not self.queue and not self.active

    def submit(self, prompt, key, *, max_new=None, on_token=None):
        # the stub "generates" prompt[i] + 1 for max_new tokens
        n = max_new if max_new is not None else len(prompt)
        row = _StubRow([int(t) + 1 for t in prompt[:n]], on_token, [])
        self.queue.append(row)

        class _H:
            def result(_, timeout=None):
                assert row not in self.active and row not in self.queue
                ids = np.asarray([row.emitted], np.int32)
                return dataclasses.make_dataclass(
                    "Out", ["response_ids", "response_len"])(
                        ids, np.asarray([ids.shape[1]]))

            def host_rows(_):
                return [np.asarray(row.emitted, np.int32)]
        return _H()

    def step(self) -> bool:
        while self.queue and len(self.active) < self.slots:
            self.active.append(self.queue.pop(0))
        if not self.active:
            return False
        self.steps += 1
        self.clock.t += self.dt          # the step's compute time
        for row in list(self.active):
            tok = row.script[len(row.emitted)]
            row.emitted.append(tok)
            if row.on_token is not None:
                row.on_token(0, tok)
            if len(row.emitted) == len(row.script):
                self.active.remove(row)
        return True


def test_driver_virtual_clock_analytic_latencies():
    """Single slot, 0.5 s/step: queueing shows up in TTFT, the driver
    sleeps the gap to a far-future arrival, and every request's stream
    equals its final response (asserted inside run())."""
    clock = VirtualClock()
    eng = StubEngine(clock, num_slots=1, dt=0.5)
    driver = RequestDriver(eng, clock=clock)
    reqs = [
        ServedRequest(rid=0, prompt=np.asarray([10, 11], np.int32),
                      arrival=0.0),
        ServedRequest(rid=1, prompt=np.asarray([20, 21], np.int32),
                      arrival=0.1),
        ServedRequest(rid=2, prompt=np.asarray([30], np.int32),
                      arrival=10.0),
    ]
    out = driver.run(reqs, jax.random.PRNGKey(0))
    # r0: steps at t=0.5, 1.0; r1 queued behind it: tokens at 1.5, 2.0;
    # engine drains, driver sleeps 8 s to r2's arrival, serves it at 10.5
    assert [r.token_t for r in out] == [[0.5, 1.0], [1.5, 2.0], [10.5]]
    assert out[0].ttft == 0.5
    assert out[1].ttft == pytest.approx(1.5 - 0.1)   # queueing included
    assert out[2].ttft == pytest.approx(0.5)
    assert out[0].tpot == out[1].tpot == pytest.approx(0.5)
    assert out[2].tpot is None
    m = compute_latency_metrics(out)
    assert m["generated_tokens"] == 5
    np.testing.assert_allclose(m["makespan_s"], 10.5)
    np.testing.assert_allclose(m["ttft_p50_s"], 0.5)
    assert eng.steps == 5                            # no busy-wait steps


def test_driver_submits_in_arrival_order_and_batches():
    """Two slots: overlapping arrivals decode concurrently; a request
    arriving mid-flight is admitted the step its arrival comes due."""
    clock = VirtualClock()
    eng = StubEngine(clock, num_slots=2, dt=1.0)
    driver = RequestDriver(eng, clock=clock)
    reqs = [ServedRequest(rid=i, prompt=np.asarray([i, i], np.int32),
                          arrival=a)
            for i, a in enumerate([0.0, 0.0, 1.5])]
    out = driver.run(reqs, jax.random.PRNGKey(0))
    assert [r.token_t for r in out] == [[1.0, 2.0], [1.0, 2.0], [3.0, 4.0]]
    assert out[2].ttft == pytest.approx(3.0 - 1.5)
    assert eng.steps == 4


def test_driver_rejects_grouped_engine():
    class _G4:
        G = 4
    with pytest.raises(AssertionError, match="1-row"):
        RequestDriver(_G4())


# =========================================================================
# streaming through the real engine
# =========================================================================


@pytest.fixture(scope="module")
def gqa_setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    return cfg, init(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("spec_k", [0, 2])
def test_streaming_matches_final_response(gqa_setup, spec_k):
    """on_token delivers every committed token exactly once, in commit
    order, INCREMENTALLY (mid-decode snapshots grow), on both the plain
    and the multi-token spec commit paths."""
    from repro.core.paged import PagedGroupEngine
    cfg, params = gqa_setup
    T = 10
    eng = PagedGroupEngine(cfg, num_slots=2, page_size=4, num_pages=32,
                           max_prompt_len=8, max_new_tokens=T, group_size=1,
                           temperature=0.7, capture_logprobs=False,
                           spec_k=spec_k, seed=0)
    eng.set_params(params)
    prompts = [np.asarray([1, 5, 6, 7, 2 + i], np.int32) for i in range(3)]
    streams = [[] for _ in prompts]

    def sink(s):
        return lambda row_idx, token_id: s.append(int(token_id))

    hs = [eng.submit(p, jax.random.fold_in(jax.random.PRNGKey(9), i),
                     on_token=sink(streams[i]))
          for i, p in enumerate(prompts)]
    partial = False
    while eng.step():
        ns = [len(s) for s in streams]
        partial = partial or any(0 < n < T for n in ns)
    assert partial, "tokens only appeared after drain — not streaming"
    for i, h in enumerate(hs):
        out = h.result(timeout=1)
        n = int(np.asarray(out.response_len)[0])
        assert streams[i] == np.asarray(out.response_ids)[0, :n].tolist()


def test_serve_requests_end_to_end(gqa_setup):
    """The full serving stack on the real engine with an explicit arrival
    trace: per-request streams are verified inside the driver; metrics and
    prefix stats come back coherent."""
    cfg, params = gqa_setup
    system = [1, 5, 6, 7, 8, 9, 10, 11]
    prompts = [np.asarray(system + [40 + i], np.int32) for i in range(4)]
    reqs, metrics, stats = serve_requests(
        cfg, prompts, max_prompt_len=12, max_new=8, num_slots=2,
        page_size=4, temperature=0.0, seed=0, prefix_cache=True,
        arrivals=np.asarray([0.0, 0.0, 0.0, 0.05]), params=params)
    assert metrics["n_requests"] == 4
    assert metrics["generated_tokens"] == sum(len(r.tokens) for r in reqs)
    assert metrics["generated_tokens"] > 0
    assert metrics["ttft_p99_s"] >= metrics["ttft_p50_s"] > 0
    assert metrics["makespan_s"] > 0 and metrics["tok_per_s"] > 0
    assert stats["prefix_hit_rate"] > 0          # shared 2-page system
    # greedy + shared system prompt: identical rids -> distinct suffixes,
    # but every request decoded SOMETHING and the stream survived the
    # driver's stream-vs-final assertion
    assert all(r.done_t is not None for r in reqs)
