"""Sharding-rule unit tests: profiles, divisibility fallbacks, cache specs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import (LOGICAL_TO_MESH, current_profile_map,
                                  profile_has, set_profile, spec_for)


@pytest.fixture(autouse=True)
def restore_profile():
    yield
    set_profile("baseline")


def _mesh_stub():
    """A Mesh-shaped stub: spec_for only reads axis_names and shape."""
    class M:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    return M()


def test_spec_resolves_divisible_dims():
    m = _mesh_stub()
    assert spec_for(m, (8, 16), ("batch", "model")) == P("data", "model")


def test_spec_skips_indivisible_dims():
    m = _mesh_stub()
    # 6 % 4 != 0 -> batch dim unsharded rather than invalid
    assert spec_for(m, (6, 16), ("batch", "model")) == P(None, "model")


def test_profiles_switch_and_restore():
    base = current_profile_map()
    set_profile("dp2")
    assert LOGICAL_TO_MESH["batch"] == ("pod", "data", "model")
    assert not profile_has("seq")
    set_profile("sp_heads")
    assert profile_has("heads") and profile_has("ffn")
    set_profile("baseline")
    assert current_profile_map() == base


def test_unknown_logical_axis_is_noop():
    m = _mesh_stub()
    # "heads" unmapped under baseline; "pod" absent from this mesh
    assert spec_for(m, (8, 8), ("heads", None)) == P(None, None)
