"""Sliding-window ring-buffer cache (DESIGN.md §Arch-applicability): the
windowed prefill ring-write and the ``idx = offset % window`` decode write
must produce the same attention outputs as a full-length cache under the
same window mask — the ring is a memory layout, not a semantics change.

The oracle is the SAME config (same window masking) over a full-length
dense cache: every offset is below the cache length, so ``off % L`` is the
identity and the cache holds every token; only the ring's slot recycling
differs. Covers GQA and MLA (both have ring paths in models/attention.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.attention import (DenseCacheBackend, attention,
                                    init_attention, make_cache)

W, LP, T, B = 8, 24, 6, 2


def _cfg(arch):
    return dataclasses.replace(reduced_config(get_config(arch)),
                               sliding_window=W)


def _pos_seg(S):
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    seg = jnp.zeros((B, S), jnp.int32)
    return pos, seg


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b"])
def test_ring_cache_matches_full_cache_oracle(arch):
    """Windowed prefill (S > window -> ring-write of the trailing window)
    followed by T ring decode steps must match a full-length cache driven
    through the identical attention (same window mask) step for step."""
    cfg = _cfg(arch)
    rng = np.random.RandomState(0)
    params = init_attention(jax.random.PRNGKey(1), cfg, jnp.float32)

    ring = make_cache(cfg, B, W, jnp.float32)          # the decode default
    full = make_cache(cfg, B, LP + T, jnp.float32)     # oracle layout
    assert ring["pos"].shape[1] == W

    x = jnp.asarray(rng.randn(B, LP, cfg.d_model), jnp.float32)
    pos, seg = _pos_seg(LP)
    out_ring, ring = attention(params, cfg, x, pos, seg,
                               cache=ring, cache_offset=0)
    out_full, full = attention(params, cfg, x, pos, seg,
                               cache=full, cache_offset=0)
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(out_full),
                               atol=1e-5)

    for t in range(T):
        xt = jnp.asarray(rng.randn(B, 1, cfg.d_model), jnp.float32)
        pt = jnp.full((B, 1), LP + t, jnp.int32)
        st = jnp.zeros((B, 1), jnp.int32)
        o_r, ring = attention(params, cfg, xt, pt, st,
                              cache=ring, cache_offset=LP + t)
        o_f, full = attention(params, cfg, xt, pt, st,
                              cache=full, cache_offset=LP + t)
        np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_f),
                                   atol=1e-5, err_msg=f"decode step {t}")


def test_ring_decode_per_row_offsets():
    """The slot engines drive the ring with PER-ROW offsets (one-hot masked
    writes); ``idx = off % window`` must land each row's token in its own
    ring slot, matching the scalar-offset path row for row."""
    cfg = _cfg("llama3.2-3b")
    rng = np.random.RandomState(2)
    params = init_attention(jax.random.PRNGKey(3), cfg, jnp.float32)
    be = DenseCacheBackend(cfg, W)
    assert be.ring

    # warm two independent caches to different depths via the scalar path
    caches, outs_scalar = [], []
    offs = [W + 3, W - 2]                    # one wrapped row, one not
    for b, depth in enumerate(offs):
        c = make_cache(cfg, 1, W, jnp.float32)
        for t in range(depth):
            xt = jnp.asarray(rng.randn(1, 1, cfg.d_model), jnp.float32)
            pt = jnp.full((1, 1), t, jnp.int32)
            st = jnp.zeros((1, 1), jnp.int32)
            o, c = attention(params, cfg, xt, pt, st, cache=c,
                             cache_offset=t)
        caches.append(c)

    # stack the rows into one 2-row cache and advance with per-row offsets
    stacked = jax.tree.map(lambda a, b2: jnp.concatenate([a, b2], axis=0),
                           caches[0], caches[1])
    x = jnp.asarray(rng.randn(B, 1, cfg.d_model), jnp.float32)
    pos = jnp.asarray([[offs[0]], [offs[1]]], jnp.int32)
    seg = jnp.zeros((B, 1), jnp.int32)
    out_rows, stacked = attention(params, cfg, x, pos, seg, cache=stacked,
                                  cache_offset=jnp.asarray(offs, jnp.int32))
    for b in range(B):
        o_ref, _ = attention(params, cfg, x[b:b + 1], pos[b:b + 1],
                             seg[b:b + 1], cache=caches[b],
                             cache_offset=offs[b])
        np.testing.assert_allclose(np.asarray(out_rows[b:b + 1]),
                                   np.asarray(o_ref), atol=1e-5)


def test_windowed_mask_actually_limits_attention():
    """Sanity guard for the oracle itself: with the window mask, a token
    far past the window must be insensitive to the earliest prompt tokens
    (full causal attention would not be)."""
    cfg = _cfg("llama3.2-3b")
    rng = np.random.RandomState(4)
    params = init_attention(jax.random.PRNGKey(5), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(1, LP, cfg.d_model), jnp.float32)
    x2 = x.at[0, 0].set(x[0, 0] + 7.0)       # perturb token 0
    pos = jnp.arange(LP, dtype=jnp.int32)[None]
    seg = jnp.zeros((1, LP), jnp.int32)
    o1, _ = attention(params, cfg, x, pos, seg)
    o2, _ = attention(params, cfg, x2, pos, seg)
    # inside the window of token 0 the outputs differ...
    assert not np.allclose(np.asarray(o1[0, 1]), np.asarray(o2[0, 1]))
    # ...but the last token (pos LP-1 >= window) cannot see token 0
    np.testing.assert_allclose(np.asarray(o1[0, -1]), np.asarray(o2[0, -1]),
                               atol=1e-6)
