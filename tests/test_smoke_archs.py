"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model <= 512, <= 4 experts) runs one forward and
one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import RLConfig
from repro.models import forward, forward_hidden, init, init_caches
from repro.optim.adam import adam_init
from repro.rl.grpo import MicroBatch, make_train_step


def _extras(cfg, B):
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model),
                                    jnp.float32)
    if cfg.vision_prefix_len:
        kw["vision_embeds"] = jnp.ones((B, cfg.vision_prefix_len, cfg.d_model),
                                       jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced_config(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    logits, _, aux = forward(params, cfg, jnp.ones((B, S), jnp.int32),
                             **_extras(cfg, B))
    S_out = S + cfg.vision_prefix_len
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = reduced_config(get_config(arch))
    rl = RLConfig(learning_rate=1e-3)
    params = init(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    B, S_tok = 2, 16
    S = S_tok + cfg.vision_prefix_len
    key = jax.random.PRNGKey(1)
    mb = MicroBatch(
        tokens=jax.random.randint(key, (B, S_tok), 0, cfg.vocab_size),
        labels=jax.random.randint(key, (B, S_tok), 0, cfg.vocab_size),
        positions=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        segments=jnp.zeros((B, S), jnp.int32),
        loss_mask=jnp.ones((B, S_tok), jnp.float32) / S_tok,
        advantages=jnp.ones((B, S_tok), jnp.float32),
        n_samples=jnp.float32(B),
        extras=_extras(cfg, B))
    step = make_train_step(cfg, rl)
    new_params, new_opt, metrics = step(params, params, params, opt, mb)
    assert int(new_opt.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params must actually move
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Cache path correctness: forward over [t0..t7] then one cached decode
    step for t8 must match the uncached forward over [t0..t8]."""
    cfg = reduced_config(get_config(arch))
    params = init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 9
    kw = _extras(cfg, B)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 3,
                              cfg.vocab_size)
    h_full, _, _, _ = forward_hidden(params, cfg, toks, **kw)

    # cache must hold vision prefix + all tokens (Vp + S <= 32 for all archs)
    caches = init_caches(params, cfg, B, 32)
    h_pre, caches, _, _ = forward_hidden(params, cfg, toks[:, :-1],
                                         caches=caches, cache_offset=0, **kw)
    Vp = cfg.vision_prefix_len
    pos = jnp.full((B, 1), S - 1 + Vp, jnp.int32)
    kw_dec = {k: v for k, v in kw.items() if k != "vision_embeds"}
    h_dec, _, _, _ = forward_hidden(params, cfg, toks[:, -1:],
                                    positions=pos,
                                    segments=jnp.zeros((B, 1), jnp.int32),
                                    caches=caches,
                                    cache_offset=S - 1 + Vp, **kw_dec)
    np.testing.assert_allclose(np.asarray(h_dec[:, 0]),
                               np.asarray(h_full[:, -1]),
                               atol=2e-3, rtol=2e-3)
