"""Shared-prompt attention equivalence (paper §4.3): packed-gradient ==
sum of per-sample gradients, exactly (f32), plus the Eq. 5 reduction ratio."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.queue import RolloutGroup
from repro.core.spa import pack_plain, pack_spa, spa_reduction_ratio
from repro.models import init
from repro.rl.grpo import jaxify, make_grad_step, group_advantages


def _group(key, G=4, Lp=12, Lr=(5, 8, 3, 8)):
    ks = np.random.RandomState(0)
    prompt = ks.randint(3, 200, size=(Lp,)).astype(np.int32)
    T = max(Lr)
    resp = np.zeros((G, T), np.int32)
    lens = np.zeros((G,), np.int32)
    for g in range(G):
        resp[g, : Lr[g]] = ks.randint(3, 200, size=(Lr[g],))
        lens[g] = Lr[g]
    rewards = np.asarray([1.0, 0.0, 0.0, 1.0], np.float32)
    return RolloutGroup(uid=0, prompt_ids=prompt, response_ids=resp,
                        response_len=lens, rewards=rewards, weight_version=0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(kl_coef=0.02, group_size=4, max_prompt_len=16,
                  max_response_len=8)
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, rl, params


def test_spa_packing_layout():
    g = _group(None)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    mb = pack_spa(g, adv, 16, 8, responses_per_row=4)
    Lp = len(g.prompt_ids)
    t, seg, pos = mb.tokens[0], mb.segments[0], mb.positions[0]
    # shared prompt occupies [0, Lp-1) with segment 0
    assert (seg[: Lp - 1] == 0).all()
    assert (pos[: Lp - 1] == np.arange(Lp - 1)).all()
    # each response slot starts with the last prompt token, restarts position
    off = Lp - 1
    for k in range(4):
        assert t[off] == g.prompt_ids[-1]
        assert pos[off] == Lp - 1
        assert seg[off] == k + 1
        off += 1 + 8
    # per-sample loss weights sum to 1 for each response
    w = mb.loss_mask[0]
    for k in range(4):
        lo = (Lp - 1) + k * 9
        s = w[lo: lo + 9].sum()
        np.testing.assert_allclose(s, 1.0, rtol=1e-5)


def test_spa_gradient_equivalence(setup):
    """grad(SPA-packed row) == grad(sum of per-sample rows) — the paper's
    exactness claim, asserted at f32."""
    cfg, rl, params = setup
    g = _group(None)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    grad_step = make_grad_step(cfg, rl)

    mb_plain = pack_plain([g], [adv], 16, 8)
    grads_plain, m_plain = grad_step(params, params, params, jaxify(mb_plain))
    mb_spa = pack_spa(g, adv, 16, 8, responses_per_row=4)
    grads_spa, m_spa = grad_step(params, params, params, jaxify(mb_spa))
    flat_p = jax.tree.leaves(grads_plain)
    flat_s = jax.tree.leaves(grads_spa)
    for a, b in zip(flat_p, flat_s):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(float(m_plain["loss"]), float(m_spa["loss"]),
                               rtol=1e-4, atol=1e-6)


def test_spa_no_cross_response_leakage(setup):
    """Perturbing response j's tokens must not change response i's logp."""
    cfg, rl, params = setup
    from repro.models import forward_hidden, token_logprobs
    g = _group(None)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    mb = pack_spa(g, adv, 16, 8, responses_per_row=4)

    def logps(tokens):
        h, _, _, _ = forward_hidden(params, cfg, jnp.asarray(tokens),
                                    positions=jnp.asarray(mb.positions),
                                    segments=jnp.asarray(mb.segments))
        return token_logprobs(params, cfg, h, jnp.asarray(mb.labels))

    base = np.asarray(logps(mb.tokens))
    Lp = len(g.prompt_ids)
    # perturb the whole response-2 slot
    t2 = mb.tokens.copy()
    lo = (Lp - 1) + 1 * 9
    t2[0, lo: lo + 9] = 7
    pert = np.asarray(logps(t2))
    # response 1 slot (segment 1) unchanged
    s0 = slice(Lp - 1, Lp - 1 + 9)
    np.testing.assert_allclose(base[0, s0], pert[0, s0], atol=1e-5)
    # response 2 slot changed
    assert np.abs(base[0, lo: lo + 9] - pert[0, lo: lo + 9]).max() > 1e-3


@pytest.mark.parametrize("Lp,Lr,K", [(1024, 64, 16), (128, 128, 8),
                                     (64, 512, 32)])
def test_spa_reduction_ratio_eq5(Lp, Lr, K):
    rho = spa_reduction_ratio(Lp, Lr, K)
    expect = (Lp ** 2 + K * Lr * (Lp + Lr)) / (K * (Lp + Lr) ** 2)
    np.testing.assert_allclose(rho, expect)
    if Lp >= 16 * Lr:
        assert rho < 2.0 / K + 0.2   # approaches 1/K for long prompts


def test_spa_align_gradient_equivalence(setup):
    """Beyond-paper spa_align=16 (tile-aligned slots, §Perf): padding slots
    to the kernel tile must not change the gradients."""
    cfg, rl, params = setup
    g = _group(None)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    grad_step = make_grad_step(cfg, rl)

    def grads_of(mb):
        gr, _ = grad_step(params, params, params, jaxify(mb))
        return gr

    g_plain = grads_of(pack_spa(g, adv, 16, 8, responses_per_row=4))
    g_align = grads_of(pack_spa(g, adv, 16, 8, responses_per_row=4,
                                align=16))
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_align)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)
