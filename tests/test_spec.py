"""Speculative-decode plane (DESIGN.md §Spec-decode): greedy spec decode
must be bitwise token-identical to the non-spec engines (group Sampler,
dense-slot cbatch, paged pool) across GQA / MLA-latent / sliding-window
cache backends; speculative pages must pre-allocate against the per-row
credits and roll back to the freelist on rejection; captured logprobs must
be the TARGET model's raw logprobs; and the shared-system-prompt serving
scenario must serve per-request suffixes off one refcounted prompt page
set via the radix prefix cache (DESIGN.md §Radix-prefix-cache — the
token-identity proof across backends lives in tests/test_radix.py).
(Distribution exactness of the sampled path is proven in
tests/test_spec_property.py under hypothesis.)

MLA identity runs with the MoE half disabled: expert-capacity ties couple
rows across batch shapes (documented at table6/§Continuous-batching), so a
k+1-token block changes routing pressure — an MoE property, not a spec
bug.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig, engine_support
from repro.core.cbatch import ContinuousBatchingSampler
from repro.core.paged import FIRST_PAGE, PagedGroupEngine
from repro.launch.train import build_pipeline
from repro.models import init
from repro.rl.rollout import Sampler
from repro.spec import SpecSampler, assemble_commit, verify_block

G, T, LP, K = 4, 10, 16, 3


def _gqa():
    return reduced_config(get_config("llama3.2-3b"))


def _mla_nomoe():
    c = reduced_config(get_config("deepseek-v2-lite-16b"))
    return dataclasses.replace(c, num_experts=0, num_experts_per_tok=0,
                               num_shared_experts=0, moe_d_ff=0,
                               first_k_dense=0, dense_d_ff=0)


def _swa():
    return dataclasses.replace(_gqa(), sliding_window=8)


VARIANTS = {"gqa": _gqa, "mla": _mla_nomoe, "swa": _swa}


@pytest.fixture(scope="module")
def setups():
    out = {}
    for name, mk in VARIANTS.items():
        cfg = mk()
        out[name] = (cfg, init(jax.random.PRNGKey(0), cfg))
    return out


PROMPT = np.asarray([1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 3, 4], np.int32)


def _assert_group_identical(out, ref):
    pr, pl = np.asarray(out.response_ids), np.asarray(out.response_len)
    rr, rl = np.asarray(ref.response_ids), np.asarray(ref.response_len)
    np.testing.assert_array_equal(pl, rl)
    for i in range(rr.shape[0]):
        np.testing.assert_array_equal(pr[i, : pl[i]], rr[i, : rl[i]])


# =========================================================================
# the exactness contract, engine by engine
# =========================================================================

@pytest.mark.parametrize("variant", ["gqa", "mla", "swa"])
def test_spec_sampler_greedy_identical(setups, variant):
    """Greedy SpecSampler == Sampler, bitwise, on every cache backend —
    the argmax chain is the same chain, just verified k+1 at a time."""
    cfg, params = setups[variant]
    key = jax.random.PRNGKey(5)
    ref = Sampler(cfg, LP, T, temperature=0.0).generate(
        params, [PROMPT] * G, key)
    spec = SpecSampler(cfg, LP, T, spec_k=K, temperature=0.0)
    _assert_group_identical(spec.generate(params, [PROMPT] * G, key), ref)
    assert spec.spec_steps > 0 and spec.committed_tokens == int(
        np.asarray(ref.response_len).sum())


def test_spec_sampler_model_draft_greedy_identical(setups):
    """The resident draft-model provider: proposals come from a separate
    half-depth model, exactness still holds (a bad draft is just
    rejected)."""
    cfg, params = setups["gqa"]
    key = jax.random.PRNGKey(7)
    ref = Sampler(cfg, LP, T, temperature=0.0).generate(
        params, [PROMPT] * G, key)
    spec = SpecSampler(cfg, LP, T, spec_k=K, temperature=0.0, draft="model")
    _assert_group_identical(spec.generate(params, [PROMPT] * G, key), ref)


def test_spec_sampler_capture_matches_sampler(setups):
    """capture_logprobs through the verify pass: greedy spec emits the
    same tokens as the Sampler, and the captured raw logprobs of those
    tokens agree fp-close (§Tri-model-capture interplay: the trainer's
    ratio sees TARGET-model behavior logprobs either way)."""
    cfg, params = setups["gqa"]
    key = jax.random.PRNGKey(11)
    ref = Sampler(cfg, LP, T, temperature=0.0,
                  capture_logprobs=True).generate(params, [PROMPT] * G, key)
    out = SpecSampler(cfg, LP, T, spec_k=K, temperature=0.0).generate(
        params, [PROMPT] * G, key)
    _assert_group_identical(out, ref)
    np.testing.assert_allclose(np.asarray(out.response_logprobs),
                               np.asarray(ref.response_logprobs),
                               atol=5e-5)


def test_cbatch_spec_greedy_identical(setups):
    """Dense-slot engine with spec: slots < requests force mid-batch
    admission; per-request outputs still match the Sampler's rows."""
    cfg, params = setups["gqa"]
    prompts = [np.asarray([1, 9, 4, 7, 3], np.int32),
               np.asarray([1, 5, 6, 7, 8, 9, 10, 11], np.int32),
               np.asarray([1, 2, 3], np.int32),
               np.asarray([4, 4, 2, 9], np.int32)]
    key = jax.random.PRNGKey(13)
    ref = Sampler(cfg, LP, T, temperature=0.0).generate(params, prompts, key)
    rr, rl = np.asarray(ref.response_ids), np.asarray(ref.response_len)
    eng = ContinuousBatchingSampler(cfg, num_slots=2, max_prompt_len=LP,
                                    max_new_tokens=T, temperature=0.0,
                                    spec_k=K)
    done = eng.run(params, prompts, key)
    assert len(done) == len(prompts)
    for c in done:
        np.testing.assert_array_equal(
            c.response_ids, rr[c.request_id, : rl[c.request_id]])
    assert eng.spec_steps > 0


@pytest.mark.parametrize("variant", ["gqa", "mla", "swa"])
def test_paged_spec_greedy_identical(setups, variant):
    """Paged pool with spec: speculative pages pre-allocate against the
    PR-3 per-row credits and roll back on rejection; output is bitwise
    identical to the Sampler and EVERY page returns to the freelist."""
    cfg, params = setups[variant]
    key = jax.random.PRNGKey(5)
    ref = Sampler(cfg, LP, T, temperature=0.0).generate(
        params, [PROMPT] * G, key)
    eng = PagedGroupEngine(cfg, num_slots=3, page_size=4, num_pages=0,
                           max_prompt_len=LP, max_new_tokens=T,
                           group_size=G, temperature=0.0, spec_k=K)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    h = eng.submit(PROMPT, key)
    while eng.step():
        pass
    _assert_group_identical(h.result(1), ref)
    assert eng.alloc.num_free == free0 and eng.idle
    assert eng.rolled_back_pages > 0, \
        "a greedy decode with imperfect drafts must roll back pages"
    # spec must finish in fewer engine steps than tokens per row
    assert eng.decode_steps < int(np.asarray(ref.response_len).max()) * 2


def test_paged_spec_sampled_rows_decorrelated(setups):
    """Sampled spec decode: rows of a group share step keys, so the verify
    draws must fold the row index — otherwise all G rollouts of a prompt
    would commit identical tokens. Also: finite captured logprobs, full
    freelist restore."""
    cfg, params = setups["gqa"]
    eng = PagedGroupEngine(cfg, num_slots=2, page_size=4, num_pages=0,
                           max_prompt_len=LP, max_new_tokens=12,
                           group_size=G, temperature=1.0, top_p=0.9,
                           spec_k=K)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    h = eng.submit(PROMPT, jax.random.PRNGKey(7))
    while eng.step():
        pass
    out = h.result(1)
    ids = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    lps = np.asarray(out.response_logprobs)
    assert (lens >= 1).all() and np.isfinite(lps).all()
    assert not all(np.array_equal(ids[0], ids[i]) for i in range(1, G)), \
        "group rows identical: per-row key fold is broken"
    assert eng.alloc.num_free == free0 and eng.idle


def test_paged_spec_tight_pool_backpressure(setups):
    """Credit safety under speculation: a pool sized for barely more than
    one group must still serve three groups (rows trickle in as pages
    free), with speculative allocation never outrunning the credits and
    all pages returning."""
    cfg, params = setups["gqa"]
    eng = PagedGroupEngine(cfg, num_slots=8, page_size=4,
                           num_pages=FIRST_PAGE + 13, max_prompt_len=LP,
                           max_new_tokens=8, group_size=G, temperature=0.0,
                           spec_k=K)
    eng.set_params(params)
    prompts = [np.asarray([1, 9, 4, 7, 2], np.int32),
               np.asarray([1, 5, 6, 7, 8, 9], np.int32),
               np.asarray([1, 2, 3], np.int32)]
    keys = jax.random.split(jax.random.PRNGKey(21), 3)
    handles = [eng.submit(p, k) for p, k in zip(prompts, keys)]
    while eng.step():
        pass
    ref = Sampler(cfg, LP, 8, temperature=0.0)
    for p, k, h in zip(prompts, keys, handles):
        _assert_group_identical(h.result(1), ref.generate(params, [p] * G, k))
    assert eng.alloc.num_free == 13 and eng.idle


def test_paged_spec_windowed_long_decode_o_window(setups):
    """Sliding window + speculation: out-of-window pages still reclaim
    mid-flight, the widened spec budget stays O(window), and a pool too
    small for the full history completes."""
    cfg, params = setups["swa"]
    T_long, page = 32, 4
    eng0 = PagedGroupEngine(cfg, num_slots=G, page_size=page, num_pages=0,
                            max_prompt_len=LP, max_new_tokens=T_long,
                            group_size=G, temperature=0.0, spec_k=K)
    budget = eng0._row_budget(T_long)
    assert budget < T_long // page, "budget must be O(window), not total"
    num_pages = FIRST_PAGE + 2 + G * budget
    eng = PagedGroupEngine(cfg, num_slots=G, page_size=page,
                           num_pages=num_pages, max_prompt_len=LP,
                           max_new_tokens=T_long, group_size=G,
                           temperature=0.0, spec_k=K)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    key = jax.random.PRNGKey(23)
    h = eng.submit(np.asarray([1, 9, 4, 7, 3, 8, 2], np.int32), key)
    while eng.step():
        pass
    ref = Sampler(cfg, LP, T_long, temperature=0.0).generate(
        params, [np.asarray([1, 9, 4, 7, 3, 8, 2], np.int32)] * G, key)
    _assert_group_identical(h.result(1), ref)
    assert eng.reclaimed_pages > 0
    assert eng.peak_pages_used <= 2 + G * budget
    assert eng.alloc.num_free == free0 and eng.idle


def test_pipeline_async_paged_spec_zero_staleness():
    """Periodic-asynchrony contract with spec decode: the verify plane is
    distribution-exact, so weight sync stays an iteration-boundary event
    and OnPolicyMonitor still sees staleness 0."""
    cfg = _gqa()
    rl = RLConfig(mode="async", batch_prompts=2, group_size=3, micro_batch=3,
                  num_inference_instances=1, max_prompt_len=24,
                  max_response_len=6, learning_rate=1e-3,
                  rollout_engine="paged", cbatch_slots=4, kv_page_size=8,
                  spec_decode=True, spec_k=2)
    sched, parts = build_pipeline(cfg, rl)
    hist = sched.run(2)
    assert len(hist) == 2
    for s in hist:
        assert s.trained_tokens > 0
        assert s.max_staleness == 0
    assert parts["queue"].outstanding == 0
    for inst in parts["pool"].instances:
        assert inst.paged_engine.idle


# =========================================================================
# shared-system-prompt serving (radix prefix cache over refcounted pages)
# =========================================================================

@pytest.mark.parametrize("spec_k", [0, K])
def test_shared_prompt_radix_suffix_prefill(setups, spec_k):
    """Requests sharing a system prompt through the radix prefix cache:
    the first admission prefills and caches the system pages, later
    requests retain them (one refcount each) and prefill only their own
    suffix — with and without the spec plane riding on top. Pages conserve
    once the pool drains: only the tree's references remain."""
    cfg, params = setups["gqa"]
    eng = PagedGroupEngine(cfg, num_slots=3, page_size=4, num_pages=48,
                           max_prompt_len=LP, max_new_tokens=12,
                           group_size=1, temperature=0.7, spec_k=spec_k,
                           prefix_cache=True)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    system = [1, 2, 3, 4, 5, 6, 7, 8]          # two full pages
    sufs = [[10, 11], [20, 21, 22, 23, 24], [30]]
    hs = [eng.submit(np.asarray(system + s, np.int32),
                     jax.random.fold_in(jax.random.PRNGKey(9), i))
          for i, s in enumerate(sufs)]
    while eng.step():
        pass
    for h in hs:
        assert h.result(1).response_len[0] > 0
    # two requests each hit the 2 cached system pages
    assert eng.prefix_hit_pages == 4 and eng.prefix_hit_rate > 0
    assert eng.idle
    # everything returned except what the tree still caches, one ref each
    tree = eng.radix.pages()
    assert eng.alloc.num_free == free0 - len(tree)
    assert all(eng.alloc.refcount(p) == 1 for p in tree)


def test_serve_shared_radix_shares_pages(setups):
    """serve_shared routes --shared-system through the radix cache: full
    prompts (system + suffix), suffix-only prefill, stats report the
    prompt pages the cache served in place of cold prefill."""
    from repro.launch.serve import serve_shared
    cfg, _ = setups["gqa"]
    system = np.arange(1, 9, dtype=np.int32)
    sufs = [np.asarray([10, 11], np.int32), np.asarray([20], np.int32),
            np.asarray([30, 31, 32], np.int32)]
    done, stats = serve_shared(cfg, system, sufs, max_prompt_len=LP,
                               max_new=10, page_size=4, seed=0, spec_k=2)
    assert len(done) == 3
    for c in done:
        assert 0 < len(c.response_ids) <= 10
    n_pp = len(system) // 4
    # requests 2 and 3 hit the cached system pages instead of re-prefilling
    assert stats["prompt_pages_saved"] == 2 * n_pp
    assert stats["prefix_hit_rate"] > 0
    assert stats["acceptance_rate"] >= 0.0


# =========================================================================
# verify-core units + kernel oracle
# =========================================================================

def test_verify_block_greedy_semantics():
    """Greedy: accept iff the draft IS the argmax; every alternative IS
    the argmax — the property that makes spec greedy bitwise-identical."""
    logits = jnp.asarray([[[0., 5., 0., 0.],     # argmax 1
                           [0., 0., 5., 0.],     # argmax 2
                           [5., 0., 0., 0.]]])   # argmax 3 -> 0
    draft = jnp.asarray([[1, 0]], jnp.int32)     # accept, reject
    keys = jnp.zeros((1, 2), jnp.uint32)
    accept, alt, lp_d, lp_a = verify_block(
        logits, draft, keys, jnp.zeros((1,), jnp.int32),
        temperature=0.0, top_p=1.0)
    np.testing.assert_array_equal(np.asarray(accept), [[True, False]])
    np.testing.assert_array_equal(np.asarray(alt), [[1, 2, 0]])
    toks, lps = assemble_commit(np.asarray(accept)[0], np.asarray(alt)[0],
                                np.asarray(draft)[0], np.asarray(lp_d)[0],
                                np.asarray(lp_a)[0])
    assert toks == [1, 2]            # accepted draft + argmax at rejection
    np.testing.assert_allclose(
        lps, np.asarray(jax.nn.log_softmax(logits[0])[
            jnp.arange(2), jnp.asarray(toks)]), rtol=1e-6)


def test_assemble_commit_walk():
    accept = np.asarray([True, True, False])
    alt = np.asarray([7, 8, 9, 10])
    draft = np.asarray([1, 2, 3])
    lp_d = np.asarray([-1., -2., -3.])
    lp_a = np.asarray([-7., -8., -9., -10.])
    toks, lps = assemble_commit(accept, alt, draft, lp_d, lp_a)
    assert toks == [1, 2, 9] and lps == [-1., -2., -9.]
    # clean sweep -> bonus token
    toks, _ = assemble_commit(np.asarray([True] * 3), alt, draft, lp_d, lp_a)
    assert toks == [1, 2, 3, 10]
    # first rejection commits the leftover resample alone
    toks, _ = assemble_commit(np.asarray([False, True, False]), alt, draft,
                              lp_d, lp_a)
    assert toks == [7]


def test_verify_kernels_match_ref_oracle():
    """The q_len=k+1 flash-verify kernels (dense + paged + MLA latent)
    against the pure-JAX oracle, windowed and full, interpret mode."""
    from repro.kernels.decode_attention import (paged_mla_verify_attention,
                                                paged_verify_attention,
                                                verify_attention)
    from repro.kernels.ref import verify_attention_ref
    rng = np.random.RandomState(0)
    B, S, H, Hkv, D, L = 2, 3, 4, 2, 8, 24
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, L, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, L, Hkv, D), jnp.float32)
    kv_pos = jnp.asarray(rng.randint(0, 14, size=(B, L)), jnp.int32)
    q_pos = jnp.asarray([[7, 8, 9], [9, 10, 11]], jnp.int32)
    for window in (None, 5):
        out = verify_attention(q, k, v, kv_pos, q_pos, block_l=8,
                               window=window, interpret=True)
        ref = verify_attention_ref(q, k, v, kv_pos, q_pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # paged wrappers agree with the oracle on the gathered context
    P, page, n_max = 6, 4, 3
    Lg = n_max * page
    k_pages = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    v_pages = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    pos_pages = jnp.asarray(rng.randint(0, 10, size=(P, page)),
                            jnp.int32).at[0].set(2 ** 30)
    table = jnp.asarray([[1, 2, 0], [3, 4, 5]], jnp.int32)
    qp = jnp.asarray([[7, 8, 9], [9, 10, 11]], jnp.int32)
    out = paged_verify_attention(q, k_pages, v_pages, pos_pages, table, qp,
                                 block_l=4, interpret=True)
    ref = verify_attention_ref(q, k_pages[table].reshape(B, Lg, Hkv, D),
                               v_pages[table].reshape(B, Lg, Hkv, D),
                               pos_pages[table].reshape(B, Lg), qp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    r, rd = 16, 8
    ckv_pages = jnp.asarray(rng.randn(P, page, r), jnp.float32)
    kr_pages = jnp.asarray(rng.randn(P, page, rd), jnp.float32)
    q_lat = jnp.asarray(rng.randn(B, S, H, r + rd), jnp.float32)
    out = paged_mla_verify_attention(q_lat, ckv_pages, kr_pages, pos_pages,
                                     table, qp, block_l=4, interpret=True)
    kk = jnp.concatenate([ckv_pages[table].reshape(B, Lg, r),
                          kr_pages[table].reshape(B, Lg, rd)],
                         -1)[:, :, None, :]
    vv = ckv_pages[table].reshape(B, Lg, r)[:, :, None, :]
    ref = verify_attention_ref(q_lat, kk, vv,
                               pos_pages[table].reshape(B, Lg), qp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# =========================================================================
# support matrix
# =========================================================================

def test_spec_support_matrix():
    """The spec plane rides the engine_support matrix: SSM/hybrid (no
    reversible per-token cache), enc-dec and VLM (group-path-only) are
    excluded with architectural reasons; everything else verifies."""
    spec_ok = {"llama3.2-3b": True, "deepseek-v2-lite-16b": True,
               "internlm2-20b": True, "qwen3-moe-235b-a22b": True,
               "mamba2-2.7b": False, "hymba-1.5b": False,
               "whisper-tiny": False, "internvl2-76b": False}
    for arch, ok in spec_ok.items():
        got, reason = engine_support(get_config(arch), "spec")
        assert got == ok, f"{arch}: expected spec={ok}, got {got} ({reason})"
        assert reason
    win = dataclasses.replace(get_config("llama3.2-3b"), sliding_window=8192)
    ok, reason = engine_support(win, "spec")
    assert ok and "window" in reason
    from repro.configs.base import engine_support_matrix
    assert "spec" in engine_support_matrix(get_config("llama3.2-3b"))
    # construction sites consult the matrix
    with pytest.raises(ValueError, match="recurrent"):
        SpecSampler(reduced_config(get_config("mamba2-2.7b")), LP, T,
                    spec_k=2)
