"""Hypothesis property test: the spec-decode rejection sampler draws
EXACTLY from the target distribution (DESIGN.md §Spec-decode).

For an arbitrary target logit vector, an arbitrary (even adversarial)
deterministic draft proposal, and the temperature/top-p filters the
engines actually sample with, the marginal of the first committed token
(accept-the-draft OR leftover-resample) must equal the filtered target
softmax — that is Proposition 1's survival condition: spec rollouts are
draws from the current policy, not an approximation of it.

Monte-Carlo over a batch of independent keys in ONE verify_block call;
derandomized so CI never flakes on sampling luck.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

given, settings, st = require_hypothesis()

from repro.rl.rollout import _filter_logits
from repro.spec.verify import verify_block

N = 4096           # keys per example; TV error ~ sqrt(V/N) ~ 0.04
SETTINGS = settings(max_examples=12, deadline=None, derandomize=True)

logit_vectors = st.lists(st.floats(-4.0, 4.0), min_size=4, max_size=6)


def _committed_first(logits_row, draft_tok, temperature, top_p, seed):
    """Marginal sample of the first committed token, N times: one
    verify_block call with k=1, the row replicated over N keys."""
    V = len(logits_row)
    lg = jnp.broadcast_to(jnp.asarray(logits_row, jnp.float32)[None, None],
                          (N, 2, V))
    draft = jnp.full((N, 1), draft_tok, jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(N))
    folds = jnp.zeros((N,), jnp.int32)
    accept, alt, _, _ = verify_block(lg, draft, keys, folds,
                                     temperature=temperature, top_p=top_p)
    return np.where(np.asarray(accept)[:, 0], draft_tok,
                    np.asarray(alt)[:, 0])


@SETTINGS
@given(logit_vectors, st.integers(0, 3),
       st.sampled_from([(1.0, 1.0), (0.7, 1.0), (1.0, 0.9)]),
       st.integers(0, 2**31 - 1))
def test_first_committed_token_matches_target_softmax(lg, draft_tok, tt,
                                                      seed):
    temperature, top_p = tt
    toks = _committed_first(lg, draft_tok, temperature, top_p, seed)
    V = len(lg)
    target = np.asarray(jax.nn.softmax(_filter_logits(
        jnp.asarray([lg], jnp.float32), temperature, top_p)[0]))
    emp = np.bincount(toks, minlength=V) / N
    tv = 0.5 * np.abs(emp - target).sum()
    assert tv < 0.06, f"TV {tv:.3f}: rejection sampling is not exact " \
                      f"(target {target}, empirical {emp})"


@SETTINGS
@given(logit_vectors, st.integers(0, 3), st.integers(0, 2**31 - 1))
def test_rejected_draft_never_recommitted(lg, draft_tok, seed):
    """The leftover distribution masks the rejected draft: a resampled
    token can never BE the draft (q = delta_d, leftover(d) = 0) — unless
    the target puts probability 1 on it, in which case it is always
    accepted."""
    V = len(lg)
    lgj = jnp.broadcast_to(jnp.asarray(lg, jnp.float32)[None, None],
                           (N, 2, V))
    draft = jnp.full((N, 1), draft_tok, jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(N))
    accept, alt, _, _ = verify_block(lgj, draft, keys,
                                     jnp.zeros((N,), jnp.int32),
                                     temperature=1.0, top_p=1.0)
    rejected_alt = np.asarray(alt)[:, 0][~np.asarray(accept)[:, 0]]
    assert (rejected_alt != draft_tok).all()


@SETTINGS
@given(logit_vectors, st.integers(0, 2**31 - 1))
def test_bonus_token_matches_target_softmax(lg, seed):
    """After a clean sweep the bonus token is a free draw from p_k — also
    exactly the target softmax."""
    V = len(lg)
    lgj = jnp.broadcast_to(jnp.asarray(lg, jnp.float32)[None, None],
                           (N, 2, V))
    # draft = argmax so acceptance is near-certain under greedy-ish peaked
    # rows; we only read alt[:, 1] (the bonus draw), whose distribution is
    # unconditional on the walk
    draft = jnp.full((N, 1), int(np.argmax(lg)), jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(N))
    _, alt, _, _ = verify_block(lgj, draft, keys,
                                jnp.zeros((N,), jnp.int32),
                                temperature=1.0, top_p=1.0)
    bonus = np.asarray(alt)[:, 1]
    target = np.asarray(jax.nn.softmax(jnp.asarray(lg, jnp.float32)))
    emp = np.bincount(bonus, minlength=V) / N
    assert 0.5 * np.abs(emp - target).sum() < 0.06
