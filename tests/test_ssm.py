"""Mamba-2 SSD correctness: the chunked state-space-duality scan must equal
the naive sequential recurrence (the definitional semantics), for any chunk
size, with and without an initial state — this is the SSM analogue of the
kernel-vs-oracle sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd, ssd_step


def naive_recurrence(x, dt, A, B, C, h0=None):
    """h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t ;  y_t = C_t . h_t"""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    R = H // G
    h = (jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(S):
        y, h = ssd_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), h


def _rand(key, Bb=2, S=24, H=4, P=8, G=2, N=6):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (Bb, S, G, N), jnp.float32)
    C = jax.random.normal(ks[4], (Bb, S, G, N), jnp.float32)
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 24, 64])
def test_ssd_matches_naive_recurrence(chunk):
    x, dt, A, B, C = _rand(jax.random.PRNGKey(0))
    y_chunked, h_chunked = ssd(x, dt, A, B, C, chunk=chunk)
    y_naive, h_naive = naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunked), np.asarray(h_naive),
                               atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence and carrying final_state == running it whole —
    the property behind decode continuation AND prefix-state sharing (the
    SSM analogue of shared-prompt attention, DESIGN.md §Arch-applicability)."""
    x, dt, A, B, C = _rand(jax.random.PRNGKey(1), S=32)
    y_full, h_full = ssd(x, dt, A, B, C, chunk=8)
    cut = 20
    y1, h1 = ssd(x[:, :cut], dt[:, :cut], A, B[:, :cut], C[:, :cut], chunk=8)
    y2, h2 = ssd(x[:, cut:], dt[:, cut:], A, B[:, cut:], C[:, cut:],
                 chunk=8, initial_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_step_extends_scan():
    """One ssd_step after a chunked scan == scan over S+1 tokens (the
    decode path)."""
    x, dt, A, B, C = _rand(jax.random.PRNGKey(2), S=17)
    y_full, h_full = ssd(x, dt, A, B, C, chunk=8)
    y_pre, h_pre = ssd(x[:, :-1], dt[:, :-1], A, B[:, :-1], C[:, :-1],
                       chunk=8)
    y_last, h_last = ssd_step(h_pre, x[:, -1], dt[:, -1], A, B[:, -1],
                              C[:, -1])
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_gradients_finite():
    x, dt, A, B, C = _rand(jax.random.PRNGKey(3), S=16)

    def loss(x, dt, A, B, C):
        y, _ = ssd(x, dt, A, B, C, chunk=8)
        return jnp.sum(y ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
