"""End-to-end behaviour tests for the periodic-asynchrony system.

These run the REAL pipeline (jitted sampler inference + tri-model GRPO
training) at CPU scale, plus integration tests of the pieces the paper's
Figure 1 composes: engine pool, generator, scheduler modes, SPA end-to-end,
checkpointing, and the serving driver.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.engine import InferenceInstance, InferencePool
from repro.data.tokenizer import Tokenizer
from repro.launch.serve import serve_batch
from repro.launch.train import build_pipeline
from repro.models import init
from repro.rl.rollout import Sampler


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("llama3.2-3b"))


def _rl(**kw) -> RLConfig:
    base = dict(mode="async", batch_prompts=2, group_size=4, micro_batch=2,
                num_inference_instances=2, max_prompt_len=32,
                max_response_len=12, learning_rate=1e-3, seed=0)
    base.update(kw)
    return RLConfig(**base)


# =========================================================================
# full pipeline with REAL jitted inference
# =========================================================================

def test_e2e_async_real_inference(cfg):
    sched, parts, = build_pipeline(cfg, _rl())[0:2]
    hist = sched.run(2)
    assert len(hist) == 2
    for s in hist:
        assert s.trained_tokens > 0
        assert s.max_staleness == 0
        assert s.tpspd > 0
    assert parts["tri"].version == 2
    # queue fully drained
    assert parts["queue"].outstanding == 0


def test_e2e_spa_mode_real_inference(cfg):
    """SPA packing end-to-end: the whole group trains as one packed row."""
    sched, parts = build_pipeline(cfg, _rl(shared_prompt_attention=True,
                                           micro_batch=4))[0:2]
    hist = sched.run(1)
    assert hist[0].trained_tokens > 0
    assert parts["tri"].version == 1


def test_e2e_training_descends(cfg):
    """The optimizer actually consumes rollouts and steps every iteration."""
    rl = _rl(batch_prompts=3, learning_rate=5e-3)
    sched, parts = build_pipeline(cfg, rl)[0:2]
    sched.run(3)
    assert parts["tri"].version == 3
    assert sched.history[-1].trained_tokens > 0


# =========================================================================
# engine / pool integration
# =========================================================================

def test_pool_round_robin_distribution(cfg):
    params = init(jax.random.PRNGKey(0), cfg)
    sampler = Sampler(cfg, 16, 4)
    insts = [InferenceInstance(i, cfg, sampler) for i in range(3)]
    pool = InferencePool(insts)
    pool.sync_weights(params, version=7)
    assert all(i.version == 7 for i in insts)
    picks = [pool.pick().inst_id for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_instance_version_tags_rollouts(cfg):
    params = init(jax.random.PRNGKey(0), cfg)
    sampler = Sampler(cfg, 16, 4)
    inst = InferenceInstance(0, cfg, sampler)
    inst.sync_weights(params, version=3)
    prompts = [np.asarray([1, 5, 9], np.int32)] * 2
    out, version = inst.generate_group(prompts, jax.random.PRNGKey(0))
    assert version == 3
    assert out.response_ids.shape == (2, 4)


# =========================================================================
# sampler behaviour
# =========================================================================

def test_sampler_eos_stops_row(cfg):
    """After EOS, a row must emit only PAD."""
    params = init(jax.random.PRNGKey(0), cfg)
    sampler = Sampler(cfg, 16, 16, temperature=1.0)
    prompts = [np.asarray([1, 7, 7], np.int32)] * 4
    out = sampler.generate(params, prompts, jax.random.PRNGKey(1))
    resp = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    for i in range(4):
        if lens[i] < 16:  # EOS observed
            assert resp[i, lens[i] - 1] == Tokenizer.EOS
            assert (resp[i, lens[i]:] == Tokenizer.PAD).all()


def test_sampler_greedy_is_deterministic(cfg):
    params = init(jax.random.PRNGKey(0), cfg)
    s = Sampler(cfg, 16, 8, temperature=0.0)
    prompts = [np.asarray([1, 4, 2, 9], np.int32)]
    a = s.generate(params, prompts, jax.random.PRNGKey(0))
    b = s.generate(params, prompts, jax.random.PRNGKey(42))
    np.testing.assert_array_equal(np.asarray(a.response_ids),
                                  np.asarray(b.response_ids))


def test_sampler_variable_prompt_lengths(cfg):
    """Left-padding: rows with different prompt lengths decode correctly."""
    params = init(jax.random.PRNGKey(0), cfg)
    s = Sampler(cfg, 16, 6)
    prompts = [np.asarray([1, 4], np.int32),
               np.asarray([1, 4, 9, 11, 13, 2, 8], np.int32)]
    out = s.generate(params, prompts, jax.random.PRNGKey(3))
    assert out.response_ids.shape == (2, 6)
    assert np.isfinite(np.asarray(out.response_len)).all()


# =========================================================================
# serving driver
# =========================================================================

def test_serve_batch_driver(cfg):
    prompts = [np.asarray([1, 5, 6, 7], np.int32)] * 3
    out, stats = serve_batch(cfg, prompts, max_prompt_len=16, max_new=8)
    assert out.response_ids.shape == (3, 8)
    assert stats["generated_tokens"] > 0
    assert stats["tok_per_s"] > 0


# =========================================================================
# checkpointing
# =========================================================================

def test_checkpoint_roundtrip(tmp_path, cfg):
    params = init(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, step=5)
    restored, step = load_checkpoint(path, params)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3,
            "opt": {"step": jnp.int32(3)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree)
    restored, _ = load_checkpoint(path, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


# =========================================================================
# pipeline overlap: async must beat sync under simulated inference latency
# =========================================================================

def test_async_overlaps_inference_and_training(cfg):
    """With a simulated remote inference service (constant latency) the
    async scheduler's wall time per iteration must be well below the sync
    scheduler's (T_infer + T_train vs max(T_infer, T_train) — §4.2.2)."""
    from repro.rl.rollout import RolloutBatch

    def scripted(prompts, key):
        G, T = len(prompts), 8
        resp = np.random.RandomState(0).randint(3, 200, size=(G, T)).astype(np.int32)
        return RolloutBatch(response_ids=jnp.asarray(resp),
                            response_len=jnp.full((G,), T, jnp.int32))

    def run(mode):
        rl = _rl(mode=mode, batch_prompts=4, num_inference_instances=1,
                 micro_batch=4)
        sched, _ = build_pipeline(cfg, rl, scripted_fn=scripted,
                                  latency_fn=lambda out: 0.15)[0:2]
        sched.run(1)          # warm the jit caches
        t0 = time.perf_counter()
        sched.run(1)
        return time.perf_counter() - t0

    t_sync = run("sync")
    t_async = run("async")
    # sync pays 4 x 0.15s of serial inference latency; async hides most of it
    assert t_async < t_sync * 0.85, (t_sync, t_async)


# =========================================================================
# architecture-agnosticism: the SAME pipeline runs attention-free (SSM) and
# MoE+MLA families end-to-end (paper claim: algorithm- and architecture-
# agnostic periodic asynchrony)
# =========================================================================

@pytest.mark.parametrize("arch", ["mamba2-2.7b", "deepseek-v2-lite-16b"])
def test_e2e_nondense_families(arch):
    cfg_a = reduced_config(get_config(arch))
    rl = _rl(batch_prompts=2, group_size=2, micro_batch=2,
             max_prompt_len=24, max_response_len=8)
    sched, parts = build_pipeline(cfg_a, rl)[0:2]
    hist = sched.run(1)
    assert hist[0].trained_tokens > 0
    assert hist[0].max_staleness == 0
    assert parts["tri"].version == 1


def test_spa_rejected_for_attention_free_archs():
    """SPA packing on an SSM would leak across responses through the
    recurrence — the scheduler must refuse and point at prefix sharing."""
    cfg_ssm = reduced_config(get_config("mamba2-2.7b"))
    rl = _rl(shared_prompt_attention=True, batch_prompts=1, group_size=2)
    sched, _ = build_pipeline(cfg_ssm, rl)[0:2]
    with pytest.raises(ValueError, match="prefix-state sharing"):
        sched.run(1)
