"""Analyzer math, cross-checked two ways (DESIGN.md §Observability).

1. Synthetic traces with hand-computed bubble/overlap/TTFT values — the
   interval algebra is verified against arithmetic done on paper, not
   against the implementation.
2. Real pipeline runs (sync and async, simulated-latency instances): the
   trace-derived infer/train/sync-gap must reproduce IterationStats to
   within 5% FROM SPANS ALONE, and the async trace's bubble fraction
   must sit strictly below sync's — the paper's Figure 3 claim, read
   off the timeline. Serving traces cross-check against
   compute_latency_metrics the same way.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import trace as otrace
from repro.obs.analyze import (analyze, analyze_file, analyze_iterations,
                               analyze_serving, render)
from repro.obs.cli import main as cli_main


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    otrace.uninstall()


def _x(name, t0_s, t1_s, **args):
    return {"ph": "X", "name": name, "pid": 0, "tid": 1,
            "ts": t0_s * 1e6, "dur": (t1_s - t0_s) * 1e6, "args": args}


# ---------------------------------------------------------------------------
# synthetic traces, hand-computed
# ---------------------------------------------------------------------------

def test_serial_iteration_hand_computed():
    """Producer [0,4], consumer [4,9] inside a 10s iteration: zero
    overlap, occupancies 4+5 of 2*10 -> bubble 0.55."""
    events = [
        _x("iteration", 0, 10, iteration=0, mode="sync"),
        _x("producer.busy", 0, 4, busy=4.0),
        _x("train.group", 4, 7),
        _x("train.update", 7, 9),
        _x("transfer.ensure", 9, 9.5, gap=0.4),
    ]
    (row,) = analyze_iterations(events)
    assert row["wall_s"] == pytest.approx(10.0)
    assert row["infer_time_s"] == pytest.approx(4.0)
    assert row["train_time_s"] == pytest.approx(5.0)
    assert row["sync_gap_s"] == pytest.approx(0.4)
    assert row["producer_occupancy_s"] == pytest.approx(4.0)
    assert row["consumer_occupancy_s"] == pytest.approx(5.0)
    assert row["overlap_s"] == pytest.approx(0.0)
    assert row["bubble_fraction"] == pytest.approx(1 - 9 / 20)
    assert row["overlap_efficiency"] == pytest.approx(0.0)


def test_overlapped_iteration_hand_computed():
    """Producer [0,8], consumer [1,9]: overlap [1,8] = 7s, bubble
    1 - 16/20 = 0.2, efficiency 7/min(8,8)."""
    events = [
        _x("iteration", 0, 10, iteration=1, mode="async"),
        _x("producer.busy", 0, 8, busy=6.5),   # charged < span extent
        _x("train.group", 1, 9),
    ]
    (row,) = analyze_iterations(events)
    assert row["infer_time_s"] == pytest.approx(6.5)   # busy attr wins
    assert row["overlap_s"] == pytest.approx(7.0)
    assert row["bubble_fraction"] == pytest.approx(0.2)
    assert row["overlap_efficiency"] == pytest.approx(7 / 8)


def test_producer_union_not_double_counted():
    """Two instances busy over the same wall window: occupancy is the
    UNION (either stage busy), while infer_time sums charged seconds."""
    events = [
        _x("iteration", 0, 10, iteration=0, mode="async"),
        _x("producer.busy", 0, 6, busy=6.0),
        _x("producer.busy", 2, 8, busy=6.0),
        _x("train.group", 0, 8),
    ]
    (row,) = analyze_iterations(events)
    assert row["producer_occupancy_s"] == pytest.approx(8.0)  # union [0,8]
    assert row["infer_time_s"] == pytest.approx(12.0)         # charged sum


def test_midpoint_assignment_and_clipping():
    """A span straddling the boundary belongs to the iteration holding
    its midpoint, but its interval is clipped to that window."""
    events = [
        _x("iteration", 0, 10, iteration=0, mode="async"),
        _x("iteration", 10, 20, iteration=1, mode="async"),
        # midpoint 11 -> iteration 1; clipped to [10, 14]
        _x("producer.busy", 8, 14, busy=6.0),
    ]
    r0, r1 = analyze_iterations(events)
    assert r0["producer_occupancy_s"] == pytest.approx(0.0)
    assert r1["producer_occupancy_s"] == pytest.approx(4.0)
    assert r1["infer_time_s"] == pytest.approx(6.0)


def test_serving_ttft_walks_back_to_arrival():
    """begin fires at submit (driver clock offsets in args): TTFT must
    include queueing delay, exactly as ServedRequest.ttft does."""
    events = [
        {"ph": "b", "name": "request", "ts": 2e6, "id": "0", "cat": "async",
         "args": {"rid": 0, "arrival": 0.5, "submit": 1.5}},
        {"ph": "i", "name": "request.token", "ts": 3e6,
         "args": {"rid": 0}},
        {"ph": "i", "name": "request.token", "ts": 4e6,
         "args": {"rid": 0}},
        {"ph": "i", "name": "request.token", "ts": 5e6,
         "args": {"rid": 0}},
        {"ph": "e", "name": "request", "ts": 5e6, "id": "0",
         "cat": "async", "args": {"rid": 0}},
    ]
    s = analyze_serving(events)
    # queue_wait = 1.0s, so arrival in trace time = 2 - 1 = 1.0s; first
    # token at 3.0s -> TTFT 2.0s; TPOT (5-3)/2 = 1.0s
    assert s["num_requests"] == 1
    assert s["ttft_p50_s"] == pytest.approx(2.0)
    assert s["tpot_p50_s"] == pytest.approx(1.0)


def test_render_and_summary():
    events = [
        _x("iteration", 0, 10, iteration=0, mode="sync"),
        _x("producer.busy", 0, 4, busy=4.0),
        _x("train.group", 4, 9),
    ]
    rep = analyze(events)
    assert rep["summary"]["mode"] == "sync"
    text = render(rep)
    assert "bubble" in text and "mean[mode=sync]" in text
    assert render({"iterations": []}).startswith("trace contains no")


def test_cli_report_and_compare(tmp_path):
    def write(path, bubble_target):
        # producer occupancy tunes the bubble: consumer fixed at [0,10],
        # so bubble = 1 - (p + 10)/20  =>  p = 20*(1 - bubble) - 10
        events = [
            _x("iteration", 0, 10, iteration=0, mode="x"),
            _x("producer.busy", 0, 20 * (1 - bubble_target) - 10),
            _x("train.group", 0, 10),
        ]
        with open(path, "w") as f:
            json.dump({"traceEvents": events}, f)
        return str(path)

    hi = write(tmp_path / "sync.json", 0.45)   # producer [0,1]
    lo = write(tmp_path / "async.json", 0.25)  # producer [0,9]
    assert cli_main(["report", hi, "--json", str(tmp_path / "r.json")]) == 0
    assert json.load(open(tmp_path / "r.json"))["summary"][
        "bubble_fraction"] == pytest.approx(0.45)
    assert cli_main(["compare", hi, lo]) == 0
    assert cli_main(["compare", lo, hi]) == 1   # wrong way round fails


# ---------------------------------------------------------------------------
# real pipeline: spans must reproduce IterationStats within 5%
# ---------------------------------------------------------------------------

def _run_traced(mode, tmp_path, iterations=3):
    from repro.configs import get_config, reduced_config
    from repro.configs.base import RLConfig
    from repro.launch.train import build_pipeline
    from repro.rl.rollout import RolloutBatch

    def scripted(prompts, key):
        G, T = len(prompts), 8
        resp = np.random.RandomState(0).randint(
            3, 200, size=(G, T)).astype(np.int32)
        return RolloutBatch(response_ids=jnp.asarray(resp),
                            response_len=jnp.full((G,), T, jnp.int32))

    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode=mode, batch_prompts=4, group_size=4, micro_batch=4,
                  num_inference_instances=1, max_prompt_len=32,
                  max_response_len=8, learning_rate=1e-3)
    sched, parts = build_pipeline(cfg, rl, scripted_fn=scripted,
                                  latency_fn=lambda out: 0.1)
    sched.run(1)                          # jit warmup, untraced
    parts["pool"].reset_stats()
    otrace.install(process_name=f"test-{mode}")
    hist = sched.run(iterations)
    path = str(tmp_path / f"{mode}.json")
    otrace.export(path)
    otrace.uninstall()
    return hist, analyze_file(path)


def _close(got, ref, rel=0.05, abs_floor=0.01):
    assert abs(got - ref) <= max(rel * abs(ref), abs_floor), (got, ref)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_trace_reproduces_iteration_stats(mode, tmp_path):
    hist, rep = _run_traced(mode, tmp_path)
    s = rep["summary"]
    assert s["iterations"] == len(hist)
    assert s["mode"] == mode
    # aggregate over the run: span-derived stage times vs the
    # scheduler's own stopwatches (same clock reads, different plumbing)
    _close(s["infer_time_s"], sum(h.infer_time for h in hist))
    _close(s["train_time_s"], sum(h.train_time for h in hist))
    _close(s["sync_gap_s"],
           sum(h.metrics["sync_gap"] for h in hist), abs_floor=0.005)


def test_async_bubble_below_sync(tmp_path):
    _, rep_sync = _run_traced("sync", tmp_path)
    _, rep_async = _run_traced("async", tmp_path)
    b_s = rep_sync["summary"]["bubble_fraction"]
    b_a = rep_async["summary"]["bubble_fraction"]
    assert b_a < b_s, (b_s, b_a)
    # serial sync sits near the 0.5 theoretical point; overlapped async
    # hides the smaller stage almost entirely
    assert b_s > 0.35
    assert rep_async["summary"]["overlap_efficiency"] > \
        rep_sync["summary"]["overlap_efficiency"]


def test_serving_trace_matches_latency_metrics(tmp_path):
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import serve_requests

    cfg = reduced_config(get_config("llama3.2-3b"))
    rng = np.random.RandomState(0)
    prompts = [np.asarray(rng.randint(2, 500, size=12), np.int32)
               for _ in range(4)]
    arrivals = np.asarray([0.0, 0.05, 0.1, 0.4])
    # untraced pass compiles the engine so the traced pass measures
    # serving, not jit
    serve_requests(cfg, prompts, max_prompt_len=32, max_new=8,
                   num_slots=2, page_size=8, temperature=0.0,
                   arrivals=arrivals)
    otrace.install(process_name="test-serve")
    _, metrics, _ = serve_requests(cfg, prompts, max_prompt_len=32,
                                   max_new=8, num_slots=2, page_size=8,
                                   temperature=0.0, arrivals=arrivals)
    path = str(tmp_path / "serve.json")
    otrace.export(path)
    otrace.uninstall()
    serving = analyze_file(path)["serving"]
    assert serving["num_requests"] == 4
    # loose bound: event emission sits a hair after the driver's own
    # timestamps, so skew is bounded by emission cost, not decode time
    _close(serving["ttft_p50_s"], metrics["ttft_p50_s"], rel=0.25,
           abs_floor=0.02)
    _close(serving["tpot_p50_s"], metrics["tpot_p50_s"], rel=0.25,
           abs_floor=0.02)
