"""Weight-plane tests (DESIGN.md §Weight-plane).

Covers the transfer subsystem end to end: reshard-plan bucketing and
bitwise round-trip (trainer profile -> inference profile), the Pallas
fused cast+copy wire kernel vs the pure-JAX cast, the versioned
double-buffered store's atomicity (torn-read regression), rollout version
gating, overlap-vs-eager param-trajectory identity, and the
checkpoint <-> weight-plane resume round-trip.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.checkpoint import load_tri, save_tri
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.engine import InferenceInstance
from repro.launch.train import build_pipeline
from repro.models import init
from repro.rl.rollout import Sampler
from repro.sharding.specs import param_specs_for_profile
from repro.transfer import (VersionedParamStore, WeightTransferService,
                            build_plan, flatten_with_keys, pack_bucket,
                            unpack_bucket)


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("llama3.2-3b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init(jax.random.PRNGKey(0), cfg)


def _assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def _rl(**kw) -> RLConfig:
    base = dict(mode="async", batch_prompts=2, group_size=4, micro_batch=2,
                num_inference_instances=2, max_prompt_len=32,
                max_response_len=12, learning_rate=1e-3, seed=0)
    base.update(kw)
    return RLConfig(**base)


# =========================================================================
# reshard plans + bucketing
# =========================================================================

def test_bucketing_covers_every_leaf_once(params):
    plan = build_plan(params, bucket_bytes=32 << 10)
    seen = [i for b in plan.buckets for i in b.indices]
    assert sorted(seen) == list(range(len(plan.leaves)))
    for b in plan.buckets:
        assert b.wire_bytes == sum(plan.leaves[i].wire_bytes
                                   for i in b.indices)
        # a bucket only exceeds the cap when a single leaf does
        assert b.wire_bytes <= 32 << 10 or len(b.indices) == 1
    assert plan.total_wire_bytes == sum(l.wire_bytes for l in plan.leaves)


def test_bucketing_deterministic(params):
    p1 = build_plan(params, bucket_bytes=16 << 10)
    p2 = build_plan(params, bucket_bytes=16 << 10)
    assert [b.indices for b in p1.buckets] == [b.indices for b in p2.buckets]


def test_oversize_leaf_gets_own_bucket():
    tree = {"big": jnp.zeros((1024,), jnp.float32),
            "s1": jnp.zeros((4,), jnp.float32),
            "s2": jnp.zeros((4,), jnp.float32)}
    plan = build_plan(tree, bucket_bytes=256)
    big = [b for b in plan.buckets
           if any(plan.leaves[i].key == "big" for i in b.indices)]
    assert len(big) == 1 and len(big[0].indices) == 1


def _push_through(plan, src_tree):
    """Stream every bucket of ``src_tree`` and rebuild the dest tree."""
    leaves = flatten_with_keys(src_tree)[1]
    slots = [None] * len(leaves)
    for b in plan.buckets:
        for i, arr in unpack_bucket(plan, b, pack_bucket(plan, leaves, b)):
            slots[i] = arr
    return jax.tree_util.tree_unflatten(plan.treedef, slots)


def test_reshard_roundtrip_bitwise(params):
    """Acceptance (a): params pushed through trainer-spec -> inference-spec
    buckets are bitwise-identical to the source tree."""
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    src = param_specs_for_profile(params, mesh, "baseline")
    dst = param_specs_for_profile(params, mesh, "infer_tp")
    plan = build_plan(params, bucket_bytes=64 << 10,
                      src_specs=src, dst_specs=dst)
    # the two profiles place FSDP-stored weights differently, so the plan
    # must actually reshard some leaves — otherwise this test proves nothing
    assert plan.num_resharded > 0
    _assert_trees_bitwise(params, _push_through(plan, params))


def test_roundtrip_bitwise_no_mesh(params):
    plan = build_plan(params, bucket_bytes=8 << 10)
    _assert_trees_bitwise(params, _push_through(plan, params))


# =========================================================================
# wire cast: Pallas fused cast+copy vs pure-JAX
# =========================================================================

@pytest.mark.parametrize("shape", [(257, 33), (5,), (16, 128), (1, 1)])
@pytest.mark.parametrize("src,dst", [("float32", "bfloat16"),
                                     ("bfloat16", "float32")])
def test_pallas_cast_matches_jax(shape, src, dst):
    """Acceptance (c): the Pallas cast kernel matches the pure-JAX path."""
    from repro.kernels.ops import transfer_cast
    x = (jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32) * 7.3
         ).astype(src)
    got = transfer_cast(x, dst)
    want = x.astype(dst)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_wire_cast_roundtrip_matches_astype():
    """fp32 masters, bf16 payload: the pushed tree equals the pure
    astype(bf16).astype(f32) reference, Pallas and JAX cast paths alike."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(1), (130, 7),
                                   jnp.float32),
            "b": jax.random.normal(jax.random.PRNGKey(2), (11,),
                                   jnp.float32)}
    want = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32),
                        tree)
    plan = build_plan(tree, bucket_bytes=1 << 20, wire_dtype="bfloat16")
    _assert_trees_bitwise(want, _push_through(plan, tree))
    from repro.kernels.ops import transfer_cast
    leaves = flatten_with_keys(tree)[1]
    slots = [None] * len(leaves)
    for b in plan.buckets:
        wire = pack_bucket(plan, leaves, b, cast_fn=transfer_cast)
        for i, arr in unpack_bucket(plan, b, wire):
            slots[i] = arr
    _assert_trees_bitwise(want, jax.tree_util.tree_unflatten(plan.treedef,
                                                             slots))


# =========================================================================
# versioned store: staged delivery, atomic flips
# =========================================================================

def _tiny_tree(v: float):
    return {"a": jnp.full((8,), v, jnp.float32),
            "b": jnp.full((3, 3), v + 0.5, jnp.float32)}


def test_store_partial_delivery_invisible():
    store = VersionedParamStore()
    store.install(_tiny_tree(0.0), 0)
    tree = _tiny_tree(1.0)
    plan = build_plan(tree, bucket_bytes=16)      # forces >= 2 buckets
    assert len(plan.buckets) >= 2
    leaves = flatten_with_keys(tree)[1]
    store.begin(1, plan)
    b0 = plan.buckets[0]
    done = store.deliver(b0, unpack_bucket(plan, b0,
                                           pack_bucket(plan, leaves, b0)))
    assert not done and store.staged_version is None
    # the active pair is untouched mid-stream
    p, v = store.snapshot()
    assert v == 0 and float(p["a"][0]) == 0.0
    with pytest.raises(AssertionError):
        store.flip()                              # incomplete staging
    for b in plan.buckets[1:]:
        done = store.deliver(b, unpack_bucket(plan, b,
                                              pack_bucket(plan, leaves, b)))
    assert done and store.staged_version == 1
    assert store.flip() == 1
    p, v = store.snapshot()
    assert v == 1 and float(p["a"][0]) == 1.0


def test_store_rejects_stale_begin_and_double_deliver():
    store = VersionedParamStore()
    store.install(_tiny_tree(0.0), 5)
    tree = _tiny_tree(1.0)
    plan = build_plan(tree, bucket_bytes=1 << 20)
    with pytest.raises(AssertionError):
        store.begin(5, plan)                      # not newer than active
    store.begin(6, plan)
    leaves = flatten_with_keys(tree)[1]
    b0 = plan.buckets[0]
    placed = unpack_bucket(plan, b0, pack_bucket(plan, leaves, b0))
    store.deliver(b0, placed)
    with pytest.raises(AssertionError):
        store.deliver(b0, placed)


def test_store_snapshot_pair_never_tears():
    """Hammer flips from one thread while readers snapshot: the (params,
    version) pair must always belong together (params carry their version
    as content)."""
    store = VersionedParamStore()
    store.install(_tiny_tree(0.0), 0)
    stop = threading.Event()
    errs = []

    def flipper():
        for v in range(1, 60):
            store.install(_tiny_tree(float(v)), v)
        stop.set()

    def reader():
        while not stop.is_set():
            p, v = store.snapshot()
            if float(p["a"][0]) != float(v):
                errs.append((float(p["a"][0]), v))

    threads = [threading.Thread(target=flipper)] + \
        [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, f"torn (params, version) pairs observed: {errs[:5]}"


def test_instance_torn_read_regression(cfg):
    """Satellite: the old ``sync_weights`` mutated ``_params``/``_version``
    without the request lock, so ``generate_group`` could read version i
    then sample with version i+1 params. Provoke the interleaving: hammer
    weight flips while groups generate, and require every returned batch's
    TOKENS to match the params of its returned VERSION (greedy decode, two
    distinguishable weight sets)."""
    sampler = Sampler(cfg, 16, 6, temperature=0.0, capture_logprobs=False)
    p0 = init(jax.random.PRNGKey(0), cfg)
    p1 = init(jax.random.PRNGKey(1), cfg)
    prompts = [np.asarray([3, 9, 4], np.int32)] * 2
    key = jax.random.PRNGKey(7)
    expected = {0: np.asarray(sampler.generate(p0, prompts, key).response_ids),
                1: np.asarray(sampler.generate(p1, prompts, key).response_ids)}
    assert not np.array_equal(expected[0], expected[1]), \
        "seeds produced indistinguishable weights; pick different seeds"
    inst = InferenceInstance(0, cfg, sampler)
    inst.sync_weights(p0, 0)
    stop = threading.Event()

    def flipper():
        v = 1
        while not stop.is_set():
            inst.sync_weights(p1 if v % 2 else p0, v)
            v += 1
            time.sleep(0.001)

    th = threading.Thread(target=flipper, daemon=True)
    th.start()
    try:
        for _ in range(12):
            out, v = inst.generate_group(prompts, key)
            np.testing.assert_array_equal(
                np.asarray(out.response_ids), expected[v % 2],
                err_msg=f"tokens sampled from a different version than {v}")
    finally:
        stop.set()
        th.join()


def test_version_gate_blocks_until_flip(cfg):
    """A request for iteration i's weights must wait for version i's flip
    rather than sample pre-flip params."""
    inst = InferenceInstance(
        0, cfg, sampler=None,
        scripted_fn=lambda p, k: ("served", inst.store.version))
    inst.sync_weights(_tiny_tree(0.0), 0)
    got = {}

    def request():
        got["out"], got["version"] = inst.generate_group(
            [np.zeros(2, np.int32)], jax.random.PRNGKey(0), min_version=2)

    th = threading.Thread(target=request)
    th.start()
    time.sleep(0.1)
    assert th.is_alive(), "request must block until version 2 lands"
    inst.sync_weights(_tiny_tree(2.0), 2)
    th.join(timeout=5)
    assert not th.is_alive() and got["version"] == 2


# =========================================================================
# transfer service: publish / overlap / failure surfacing
# =========================================================================

def _scripted_instances(n):
    return [InferenceInstance(i, cfg=None, sampler=None,
                              scripted_fn=lambda p, k: None)
            for i in range(n)]


def test_service_eager_publish_flips_all():
    insts = _scripted_instances(3)
    svc = WeightTransferService(insts, bucket_bytes=32)
    tree = _tiny_tree(4.0)
    svc.publish(tree, 0)
    assert [i.store.version for i in insts] == [0, 0, 0]
    for i in insts:
        _assert_trees_bitwise(tree, i.store.snapshot()[0])
    assert svc.bytes_streamed == svc.plan.total_wire_bytes
    assert svc.buckets_streamed == len(svc.plan.buckets)


def test_service_overlap_publish_and_boundary_barrier():
    insts = _scripted_instances(2)
    svc = WeightTransferService(insts, bucket_bytes=32, wire_latency=0.005)
    svc.ensure(_tiny_tree(0.0), 0)                # first boundary: eager
    assert svc.gaps[-1]["mode"] == "eager"
    svc.publish_async(_tiny_tree(1.0), 1)         # overlapped stream
    time.sleep(0.2)                               # the trainer's tail
    v = svc.ensure(_tiny_tree(1.0), 1)
    assert v == 1 and svc.gaps[-1]["mode"] in ("overlap", "noop")
    assert svc.gaps[-1]["gap"] < 0.1              # wire time was hidden
    for i in insts:
        p, ver = i.store.snapshot()
        assert ver == 1 and float(p["a"][0]) == 1.0


def test_service_stream_failure_surfaces_at_boundary():
    insts = _scripted_instances(1)
    svc = WeightTransferService(insts, bucket_bytes=32,
                                wire_dtype="not-a-dtype")
    svc.publish_async(_tiny_tree(0.0), 0)
    with pytest.raises(RuntimeError, match="weight-plane"):
        svc.ensure(_tiny_tree(0.0), 0)


def test_stream_failure_poisons_version_gate():
    """The boundary submits version-gated requests BEFORE the flip
    barrier; a failed stream must poison the gate so those requests error
    out instead of wedging forever with the instance lock held."""
    insts = _scripted_instances(1)
    svc = WeightTransferService(insts, bucket_bytes=32,
                                wire_dtype="not-a-dtype")
    svc.publish_async(_tiny_tree(0.0), 0)
    with pytest.raises(RuntimeError):
        svc.ensure(_tiny_tree(0.0), 0)
    with pytest.raises(RuntimeError, match="stream failed"):
        insts[0].store.wait_version(0, timeout=5)
    # a later successful publish clears the poison and serves again
    good = WeightTransferService(insts, bucket_bytes=32)
    good.publish(_tiny_tree(1.0), 1)
    p, v = insts[0].store.wait_version(1, timeout=5)
    assert v == 1 and float(p["a"][0]) == 1.0


# =========================================================================
# scheduler integration: gating + trajectory identity (acceptance b)
# =========================================================================

def _versions_probe(sched):
    """Record (group weight_version, consuming iteration version) pairs."""
    pairs = []
    orig = sched.monitor.check

    def probe(group, current):
        pairs.append((group.weight_version, current))
        return orig(group, current)

    sched.monitor.check = probe
    return pairs


@pytest.mark.parametrize("mode,iters", [("sync", 3), ("async", 3)])
def test_overlap_trajectory_identical_to_eager(cfg, mode, iters):
    """Acceptance (b): with overlap enabled every consumed rollout's
    weight_version equals the consuming iteration, and the param
    trajectory is IDENTICAL to the eager-sync baseline under a fixed key.
    (async uses one group/iteration so consumption order — and thus fp
    accumulation order — is deterministic across runs.)"""
    n_prompts = 2 if mode == "sync" else 1

    def run(overlap):
        rl = _rl(mode=mode, batch_prompts=n_prompts,
                 transfer_overlap=overlap, transfer_bucket_bytes=8 << 10)
        sched, parts = build_pipeline(cfg, rl, seed=0)
        pairs = _versions_probe(sched)
        hist = sched.run(iters)
        assert all(s.max_staleness == 0 for s in hist)
        assert all(wv == cv for wv, cv in pairs), pairs
        assert len(pairs) == n_prompts * iters
        return parts["tri"].policy

    _assert_trees_bitwise(run(True), run(False))


def test_overlap_paged_engine_deferred_flips(cfg):
    """Paged instances can't flip mid-decode (set_params asserts
    quiescence): with overlap on, their flips defer to the boundary after
    the queue drain — the run must stay strictly on-policy."""
    rl = _rl(rollout_engine="paged", batch_prompts=2, group_size=4,
             cbatch_slots=8, transfer_overlap=True)
    sched, parts = build_pipeline(cfg, rl, seed=0)
    hist = sched.run(2)
    assert all(s.max_staleness == 0 for s in hist)
    assert parts["tri"].version == 2
    # iterations 0/1 flipped versions 0/1 at their boundaries; the final
    # publish (version 2) streamed in the background and — paged flips
    # being deferred — sits fully staged awaiting the next boundary
    for inst in parts["pool"].instances:
        assert inst.store.version == 1
        assert inst.store.staged_version == 2


def test_offpolicy_runs_through_weight_plane(cfg):
    """The off-policy baseline syncs with rollouts in flight: flips must
    land without waiting on busy instances (snapshot isolation), staleness
    measured as before."""
    rl = _rl(mode="async_offpolicy", staleness_eta=1, batch_prompts=2,
             transfer_overlap=True)
    sched, _ = build_pipeline(cfg, rl, seed=0)
    hist = sched.run(3)
    assert max(s.max_staleness for s in hist) >= 1


def test_sync_gap_metric_reported(cfg):
    rl = _rl(mode="sync", batch_prompts=1, transfer_overlap=True)
    sched, _ = build_pipeline(cfg, rl, seed=0)
    hist = sched.run(2)
    assert all("sync_gap" in s.metrics for s in hist)
    assert all(s.metrics["sync_gap"] >= 0.0 for s in hist)


# =========================================================================
# checkpoint <-> weight-plane round trip (satellite)
# =========================================================================

def test_checkpoint_restores_versioned_store(tmp_path, cfg, params):
    """save/load with shardings: the tri-model version survives, and a
    service publish of the restored tree brings every store to exactly
    that version with bitwise-identical params."""
    from repro.core.trimodel import TriModelState
    from repro.sharding.specs import param_specs
    tri = TriModelState.create(params)
    tri.version = 7
    path = str(tmp_path / "ck")
    save_tri(path, tri)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    like = TriModelState.create(jax.tree.map(jnp.zeros_like, params))
    restored = load_tri(path, like, shardings=param_specs(params, mesh))
    assert restored.version == 7
    _assert_trees_bitwise(params, restored.policy)
    insts = _scripted_instances(2)
    svc = WeightTransferService(insts, bucket_bytes=32 << 10)
    svc.publish(restored.policy, restored.version)
    for i in insts:
        p, v = i.store.snapshot()
        assert v == 7
        _assert_trees_bitwise(params, p)


def test_resume_is_step_identical(tmp_path, cfg):
    """A run checkpointed at iteration 2 and resumed in a FRESH pipeline
    is step-identical to the uninterrupted 4-iteration run (fixed key):
    same param trajectory bitwise, version carried through the store."""
    rl = _rl(mode="sync", batch_prompts=2, transfer_overlap=True)

    sched_a, parts_a = build_pipeline(cfg, rl, seed=0)
    sched_a.run(4)

    sched_b, parts_b = build_pipeline(cfg, rl, seed=0)
    sched_b.run(2)
    path = str(tmp_path / "resume")
    save_tri(path, parts_b["tri"])
    resume_key = sched_b._key

    sched_c, parts_c = build_pipeline(cfg, rl, seed=0)
    load_tri(path, parts_c["tri"])
    assert parts_c["tri"].version == 2
    list(parts_c["loader"].batches(2))       # batches 0-1 consumed pre-save
    sched_c.run(2, key=resume_key)

    assert parts_c["tri"].version == parts_a["tri"].version == 4
    _assert_trees_bitwise(parts_a["tri"].policy, parts_c["tri"].policy)
    _assert_trees_bitwise(parts_a["tri"].opt.mu, parts_c["tri"].opt.mu)
    # the pool's stores carry the resumed version forward
    assert all(i.store.version == 4 for i in parts_c["pool"].instances)
